"""Dependable serving fleet: routing determinism, admission control,
bit-exact failover across model families, weight-SEU recovery
(quarantine → checkpoint reload → re-verify → readmit), DMR pair-serving,
deadlines, metrics export, and the fleet-level campaign certification.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import CampaignSpec, classify_counts, resolve_fault_model, trial_keys
from repro.configs import registry
from repro.core import fault_injection as fi
from repro.core.dependability import Policy
from repro.fleet import Fleet, ReplicaState, Router
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Engine, Request

jax.config.update("jax_platform_name", "cpu")

PROMPTS = [[5, 9, 2], [3, 1, 4, 1], [2, 7], [8, 8, 6], [1, 6, 1, 8]]
N_NEW = 5


def greedy_reference(cfg, params, prompt, n_new, max_len=96):
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = model_api.prefill(cfg, params, toks, max_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = model_api.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


@pytest.fixture(scope="module", params=["smollm-135m", "rwkv6-1.6b"])
def family_fleet(request):
    """One 2-replica fleet per model family (compiled once, reset per test)."""
    cfg = reduced(registry.get(request.param))
    params = model_api.init_params(cfg, jax.random.key(0))
    fleet = Fleet(cfg, params, n_replicas=2, policy=Policy.NONE,
                  capacity=2, max_len=96, prefill_pad=8, scrub_every=3)
    return cfg, params, fleet


@pytest.fixture(scope="module")
def smollm_fleet():
    cfg = reduced(registry.get("smollm-135m"))
    params = model_api.init_params(cfg, jax.random.key(0))
    fleet = Fleet(cfg, params, n_replicas=3, policy=Policy.NONE,
                  capacity=2, max_len=96, prefill_pad=8, scrub_every=3)
    return cfg, params, fleet


def _serve(fleet, prompts, policy, n_new=N_NEW, mid_run=None):
    """Reset + submit + (optional mid-run drill) + drain; returns requests."""
    fleet.reset(policy=policy)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        assert fleet.submit(r)
    if mid_run is not None:
        fleet.tick()
        fleet.tick()
        mid_run(fleet)
    fleet.run()
    return reqs


# ---------------------------------------------------------------------------
# baseline correctness: a fleet serves exactly what one engine would
# ---------------------------------------------------------------------------


def test_fleet_matches_single_engine_reference(family_fleet):
    cfg, params, fleet = family_fleet
    reqs = _serve(fleet, PROMPTS, Policy.NONE)
    for r, p in zip(reqs, PROMPTS):
        assert r.uid in fleet.released
        assert r.output == greedy_reference(cfg, params, p, N_NEW), f"req {r.uid}"
    assert fleet.metrics.released == len(PROMPTS)


# ---------------------------------------------------------------------------
# router: determinism + admission control
# ---------------------------------------------------------------------------


def test_hash_router_is_deterministic_and_stable(smollm_fleet):
    _, _, fleet = smollm_fleet
    fleet.reset()
    router = Router("hash")
    picks = [router.pick(uid, fleet.replicas).rid for uid in range(20)]
    assert picks == [router.pick(uid, fleet.replicas).rid for uid in range(20)]
    assert len(set(picks)) > 1            # spreads over replicas


def test_least_loaded_router_prefers_idle_lowest_rid(smollm_fleet):
    _, _, fleet = smollm_fleet
    fleet.reset()
    router = Router("least_loaded")
    assert router.pick(0, fleet.replicas).rid == 0     # all idle → lowest rid
    fleet.replicas[0].engine.submit(Request(uid=90, prompt=[1], max_new_tokens=2))
    assert router.pick(1, fleet.replicas).rid == 1     # 0 now loaded


def test_admission_control_rejects_when_full(smollm_fleet):
    _, _, fleet = smollm_fleet
    fleet.reset()
    old = fleet.router
    try:
        fleet.router = Router("least_loaded", admit_limit=1)
        assert fleet.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
        assert fleet.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=2))
        assert fleet.submit(Request(uid=2, prompt=[1, 2], max_new_tokens=2))
        # all three replicas now hold one request each — fleet is full
        assert not fleet.submit(Request(uid=3, prompt=[1, 2], max_new_tokens=2))
        assert fleet.metrics.rejected == 1
        fleet.run()
        assert fleet.metrics.released == 3
    finally:
        fleet.router = old


def test_deadline_miss_expires_request(smollm_fleet):
    _, _, fleet = smollm_fleet
    fleet.reset()
    req = Request(uid=0, prompt=[5, 9, 2], max_new_tokens=30)
    assert fleet.submit(req, deadline_ticks=2)
    fleet.run()
    assert fleet.metrics.deadline_misses == 1
    assert req.uid not in fleet.released


# ---------------------------------------------------------------------------
# deterministic failover — same tokens with or without a mid-decode kill,
# across two model families (satellite requirement)
# ---------------------------------------------------------------------------


def test_failover_after_kill_is_bit_exact(family_fleet):
    cfg, params, fleet = family_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.NONE)]

    reqs = _serve(fleet, PROMPTS, Policy.NONE,
                  mid_run=lambda f: f.kill_replica(0))
    assert fleet.replicas[0].state is ReplicaState.DEAD
    assert fleet.metrics.failovers > 0
    assert [list(r.output) for r in reqs] == golden
    assert fleet.metrics.released == len(PROMPTS)


def test_heartbeat_timeout_declares_paused_replica_dead(smollm_fleet):
    _, _, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.NONE)]
    reqs = _serve(fleet, PROMPTS, Policy.NONE,
                  mid_run=lambda f: f.pause_replica(0))
    assert any("heartbeat timeout" in e for e in fleet.supervisor.events)
    assert [list(r.output) for r in reqs] == golden


# ---------------------------------------------------------------------------
# weight-SEU recovery: quarantine → checkpoint reload → re-verify → readmit
# ---------------------------------------------------------------------------


def _corrupt_weights(fleet, key=jax.random.key(11)):
    victim = fleet.replicas[0]
    victim.engine.params = fi.inject_pytree_with(
        victim.engine.params, key, fi.flip_one_bit)


def test_abft_scrub_recovers_weight_seu(smollm_fleet):
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.ABFT)]
    assert fleet.metrics.detections == 0          # clean pass: no false alarms

    reqs = _serve(fleet, PROMPTS, Policy.ABFT,
                  mid_run=lambda f: _corrupt_weights(f))
    assert fleet.metrics.detections >= 1
    assert fleet.metrics.recoveries == 1
    assert fleet.replicas[0].state is ReplicaState.HEALTHY   # readmitted
    assert fleet.replicas[0].scrub() == []                   # re-verified
    assert [list(r.output) for r in reqs] == golden          # zero SDC
    assert fleet.metrics.released == len(PROMPTS)


def test_dmr_detects_transient_decode_fault(smollm_fleet):
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.DMR)]
    assert fleet.metrics.detections == 0

    def strike(f):
        v = f.replicas[0]
        v.engine.tokens = v.engine.tokens ^ 1     # flip every active token

    reqs = _serve(fleet, PROMPTS, Policy.DMR, mid_run=strike)
    assert fleet.metrics.detections >= 1
    assert fleet.metrics.recoveries == 0          # transient: weights clean
    assert [list(r.output) for r in reqs] == golden
    assert fleet.metrics.released == len(PROMPTS)


# ---------------------------------------------------------------------------
# CKPT fleet policy: incremental restore + decode-state rollback
# ---------------------------------------------------------------------------


def test_ckpt_weight_seu_incremental_restore(smollm_fleet):
    """CKPT is scrub-gated like ABFT but recovers by restoring only the
    corrupted leaves from the golden checkpoint — measured, incremental."""
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.CKPT)]
    assert fleet.metrics.detections == 0          # clean pass: no false alarms

    reqs = _serve(fleet, PROMPTS, Policy.CKPT,
                  mid_run=lambda f: _corrupt_weights(f))
    m = fleet.metrics
    assert m.detections >= 1
    assert m.recoveries == 1
    assert m.incremental_restores == 1            # partial restore served it
    assert m.full_reloads == 0
    assert m.leaves_restored >= 1
    assert m.recovery_seconds.count == 1 and m.recovery_seconds.sum > 0
    assert m.to_json()["recovery_mean_seconds"] > 0
    assert fleet.replicas[0].state is ReplicaState.HEALTHY
    assert [list(r.output) for r in reqs] == golden
    assert m.released == len(PROMPTS)


def test_ckpt_decode_state_seu_rolls_back_in_place(smollm_fleet):
    """Transient SEU in the token buffer under CKPT: the engine's own
    snapshot rollback heals it — no failover, stream golden."""
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.CKPT)]

    def strike(f):
        v = f.replicas[0]
        v.engine.tokens = fi.flip_one_bit(v.engine.tokens, jax.random.key(5))

    reqs = _serve(fleet, PROMPTS, Policy.CKPT, mid_run=strike)
    m = fleet.metrics
    assert m.state_scrub_detections >= 1
    assert m.state_rollbacks >= 1                 # healed in place…
    assert m.recoveries == 0                      # …not via quarantine
    assert [list(r.output) for r in reqs] == golden
    assert m.released == len(PROMPTS)


def test_recovery_survives_crashed_checkpoint_writer(smollm_fleet):
    """Crash-consistency at fleet level: an orphaned step_N.tmp (writer
    killed mid-publish) in the golden checkpoint dir must be invisible —
    quarantine-recovery restores from the durable manifest and the engine
    state it rebuilds is bit-exact (same released stream)."""
    from pathlib import Path
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.CKPT)]

    orphan = Path(fleet.ckpt_dir) / "step_0000000099.tmp"
    orphan.mkdir()
    (orphan / "chunks.npz").write_bytes(b"torn write")
    try:
        reqs = _serve(fleet, PROMPTS, Policy.CKPT,
                      mid_run=lambda f: _corrupt_weights(f))
        assert fleet.metrics.recoveries == 1
        assert fleet.replicas[0].scrub() == []         # bit-exact params
        assert [list(r.output) for r in reqs] == golden
    finally:
        if orphan.exists():
            import shutil
            shutil.rmtree(orphan)


def test_abft_decode_state_seu_drains_and_replays(smollm_fleet):
    """The same strike under ABFT: detect-only scrub, fleet drains the
    replica and replays on verified replicas — stream still golden."""
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.ABFT)]

    def strike(f):
        v = f.replicas[0]
        v.engine.tokens = fi.flip_one_bit(v.engine.tokens, jax.random.key(5))

    reqs = _serve(fleet, PROMPTS, Policy.ABFT, mid_run=strike)
    m = fleet.metrics
    assert m.state_scrub_detections >= 1
    assert m.state_drains >= 1
    assert m.state_rollbacks == 0
    assert [list(r.output) for r in reqs] == golden
    assert m.released == len(PROMPTS)


# ---------------------------------------------------------------------------
# metrics export
# ---------------------------------------------------------------------------


def test_metrics_json_round_trip(smollm_fleet, tmp_path):
    _, _, fleet = smollm_fleet
    _serve(fleet, PROMPTS, Policy.ABFT)
    m = fleet.metrics.to_json()
    for k in ("released", "p50_latency_ticks", "p99_latency_ticks",
              "tokens_per_tick", "recoveries", "failovers",
              "lost_work_bound_tokens", "scrubs"):
        assert k in m, k
    assert m["released"] == len(PROMPTS)
    assert m["p50_latency_ticks"] <= m["p99_latency_ticks"]
    p = fleet.metrics.dump(tmp_path / "fleet.json")
    assert json.loads(p.read_text())["released"] == len(PROMPTS)
    report = fleet.report()
    assert len(report["replicas"]) == 3
    json.dumps(report)                            # fully serializable


# ---------------------------------------------------------------------------
# fleet campaign certification (acceptance criteria)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fleet_case():
    from repro.campaign.runner import build_case
    return build_case("fleet", 0)


def test_fleet_campaign_abft_zero_sdc_none_nonzero_100_trials(fleet_case):
    """≥100 seeded weight-SEU trials: ABFT scrub+failover ⇒ every trial
    detected_corrected and fleet SDC = 0; NONE ⇒ nonzero SDC."""
    case = fleet_case
    fault = resolve_fault_model("single_bitflip")

    spec_a = CampaignSpec("fleet", Policy.ABFT, "weights",
                          "single_bitflip", trials=100, seed=0)
    det, mis = case.run_trials(Policy.ABFT, "weights", fault.apply,
                               trial_keys(spec_a))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_uncorrected"] == 0
    assert counts["detected_corrected"] == 100    # every flip caught + healed

    spec_n = CampaignSpec("fleet", Policy.NONE, "weights",
                          "single_bitflip", trials=100, seed=0)
    det, mis = case.run_trials(Policy.NONE, "weights", fault.apply,
                               trial_keys(spec_n))
    counts = classify_counts(det, mis)
    assert not det.any()
    assert counts["sdc"] > 0                      # undefended fleet corrupts


def test_fleet_campaign_dmr_covers_transient_site(fleet_case):
    case = fleet_case
    fault = resolve_fault_model("single_bitflip")
    spec = CampaignSpec("fleet", Policy.DMR, "decode_state",
                        "single_bitflip", trials=40, seed=1)
    det, mis = case.run_trials(Policy.DMR, "decode_state", fault.apply,
                               trial_keys(spec))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_corrected"] > 0


@pytest.mark.parametrize("policy", [Policy.ABFT, Policy.CKPT])
@pytest.mark.parametrize("site", ["decode_state", "kv_cache"])
def test_fleet_scrub_policies_cover_transient_sites(fleet_case, policy, site):
    """The decode-state scrub closes the old ABFT blind spot: transient
    SEUs in the KV cache / token buffer are detected by checksum and healed
    — CKPT by in-place engine rollback, ABFT by drain + failover — with
    zero SDC on the released stream."""
    case = fleet_case
    fault = resolve_fault_model("single_bitflip")
    spec = CampaignSpec("fleet", policy, site, "single_bitflip",
                        trials=20, seed=3)
    det, mis = case.run_trials(policy, site, fault.apply, trial_keys(spec))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_corrected"] == 20      # detected AND healed
    stats = case.drain_recovery_stats()
    assert stats["faults_recovered"] >= 20
    assert stats["recovery_ms_mean"] > 0.0


def test_fleet_ckpt_weight_seu_recovers_incrementally(fleet_case):
    """CKPT fleet trial: weight SEU → scrub detect → *incremental* restore
    of only the corrupted leaves → released stream golden, recovery timed."""
    case = fleet_case
    fault = resolve_fault_model("single_bitflip")
    spec = CampaignSpec("fleet", Policy.CKPT, "weights",
                        "single_bitflip", trials=20, seed=4)
    det, mis = case.run_trials(Policy.CKPT, "weights", fault.apply,
                               trial_keys(spec))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_corrected"] == 20
    m = case.fleet.metrics
    assert m.incremental_restores >= 1             # partial restore, not reload
    assert m.full_reloads == 0
    assert m.leaves_restored >= 1
