"""Shared test fixtures + optional-dependency shims.

``hypothesis`` is an optional dependency of this repo: the property-based
tests want it, but the container image does not ship it and tier-1 must
stay runnable regardless.  When the real package is absent we install a
stub into ``sys.modules`` *before collection* that turns every
``@given(...)``-decorated test into an explicit skip (with a clear reason)
while leaving the example-based tests in the same files untouched.
"""
from __future__ import annotations

import sys
import types

import pytest


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401
        return                                   # real package available
    except ImportError:
        pass

    skip_mark = pytest.mark.skip(
        reason="hypothesis not installed — property-based test skipped")

    def given(*_args, **_kwargs):
        def deco(fn):
            return skip_mark(fn)
        return deco

    def settings(*_args, **_kwargs):             # @settings(...) — identity
        def deco(fn):
            return fn
        return deco

    for attr in ("register_profile", "load_profile", "get_profile"):
        setattr(settings, attr, lambda *a, **k: None)

    def assume(_cond=True):
        return True

    class _Strategy:
        """Inert strategy object: supports the combinator API shape
        (map/filter/flatmap/chaining) so module-level strategy definitions
        evaluate without the real library."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, _name):
            return self

        def __or__(self, _other):
            return self

    class _Strategies(types.ModuleType):
        def __getattr__(self, _name):            # st.integers, st.lists, ...
            return _Strategy()

    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    stub.assume = assume
    stub.example = lambda *a, **k: (lambda fn: fn)
    stub.note = lambda *a, **k: None
    stub.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    stub.strategies = _Strategies("hypothesis.strategies")
    stub.__is_repro_stub__ = True
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = stub.strategies


_install_hypothesis_stub()
