"""Serving engine: continuous batching, correctness vs plain decode,
snapshot/rollback fault recovery."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Engine, Request

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def served():
    cfg = reduced(registry.get("smollm-135m"))
    params = model_api.init_params(cfg, jax.random.key(0))
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new, max_len=96):
    """Plain prefill + decode loop (no engine)."""
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = model_api.prefill(cfg, params, toks, max_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = model_api.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def test_single_request_matches_reference(served):
    cfg, params = served
    prompt = [5, 9, 2, 7]
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    req = Request(uid=0, prompt=prompt, max_new_tokens=6)
    eng.submit(req)
    eng.run()
    want = greedy_reference(cfg, params, prompt, 6)
    assert req.output == want


def test_batched_requests_match_individual(served):
    """Continuous batching must not change any request's tokens."""
    cfg, params = served
    prompts = [[1, 2, 3], [9, 8, 7, 6, 5], [4, 4]]
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, p in zip(reqs, prompts):
        assert r.output == greedy_reference(cfg, params, p, 5), f"req {r.uid}"


def test_more_requests_than_capacity(served):
    cfg, params = served
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    reqs = [Request(uid=i, prompt=[i + 1, i + 2], max_new_tokens=3)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert all(len(r.output) == 3 for r in reqs)
    assert stats.tokens_out >= 5 * 2        # decode tokens counted

def test_snapshot_rollback_replays_identically(served):
    """Device-fault drill: corrupt decode state, roll back, tokens identical."""
    cfg, params = served
    prompt = [3, 1, 4, 1, 5]
    want = greedy_reference(cfg, params, prompt, 8)

    eng = Engine(cfg, params, capacity=1, max_len=96, prefill_pad=8,
                 snapshot_every=2)
    req = Request(uid=0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    for _ in range(4):
        eng.step()
    # SEU strikes the decode token buffer
    eng.tokens = eng.tokens.at[0].set(123)
    lost = eng.restore_snapshot()   # rollback restores tokens AND req.output
    assert lost >= 0
    eng.run()
    assert req.output == want
