"""Serving engine: continuous batching, correctness vs plain decode,
snapshot/rollback fault recovery."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Engine, Request

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def served():
    cfg = reduced(registry.get("smollm-135m"))
    params = model_api.init_params(cfg, jax.random.key(0))
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new, max_len=96):
    """Plain prefill + decode loop (no engine)."""
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = model_api.prefill(cfg, params, toks, max_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = model_api.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def test_single_request_matches_reference(served):
    cfg, params = served
    prompt = [5, 9, 2, 7]
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    req = Request(uid=0, prompt=prompt, max_new_tokens=6)
    eng.submit(req)
    eng.run()
    want = greedy_reference(cfg, params, prompt, 6)
    assert req.output == want


def test_batched_requests_match_individual(served):
    """Continuous batching must not change any request's tokens."""
    cfg, params = served
    prompts = [[1, 2, 3], [9, 8, 7, 6, 5], [4, 4]]
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, p in zip(reqs, prompts):
        assert r.output == greedy_reference(cfg, params, p, 5), f"req {r.uid}"


def test_more_requests_than_capacity(served):
    cfg, params = served
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    reqs = [Request(uid=i, prompt=[i + 1, i + 2], max_new_tokens=3)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert all(len(r.output) == 3 for r in reqs)
    assert stats.tokens_out >= 5 * 2        # decode tokens counted

def test_snapshot_restore_round_trips_stats_and_finished_requests(served):
    """Regression: restore_snapshot must roll back tokens_out (not just
    steps) and resurrect requests that finished after the snapshot, so
    token accounting never inflates across a replay."""
    cfg, params = served
    prompts = [[5, 9, 2, 7], [3, 1]]

    def fresh():
        eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                     snapshot_every=2)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, (8, 3)))]
        for r in reqs:
            eng.submit(r)
        return eng, reqs

    eng, reqs = fresh()
    clean_stats = eng.run()
    golden = [list(r.output) for r in reqs]

    eng, reqs = fresh()
    eng.step()
    eng.step()          # req 1 (max_new=3) finishes here, after the snapshot
    assert reqs[1].finished_at > 0
    eng.tokens = eng.tokens.at[0].set(123)        # SEU in decode state
    eng.restore_snapshot()
    # the finished request was resurrected — its post-snapshot tokens were
    # produced after the corruption window and must be re-decoded
    assert reqs[1].finished_at == 0.0
    eng.run()
    assert [list(r.output) for r in reqs] == golden
    assert eng.stats.steps == clean_stats.steps
    assert eng.stats.tokens_out == clean_stats.tokens_out
    assert eng.stats.tokens_per_step() == clean_stats.tokens_per_step()
    assert eng.stats.replays == 1


def test_restore_requeues_requests_admitted_after_snapshot(served):
    """A request admitted after the snapshot loses its prefill rows in the
    cache rollback; restore must send it back to the queue, not strand it."""
    cfg, params = served
    prompts = [[5, 9, 2], [4, 4, 8, 1]]
    golden = [greedy_reference(cfg, params, p, 3) for p in prompts]

    eng = Engine(cfg, params, capacity=1, max_len=96, prefill_pad=8,
                 snapshot_every=4)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()      # snapshot@0; req0 finishes; req1 admitted at step 2
    assert reqs[0].finished_at > 0 and reqs[1].output is not None
    eng.tokens = eng.tokens.at[0].set(77)
    eng.restore_snapshot()
    assert reqs[1] in eng.queue                   # requeued, prefill redone
    eng.run()
    assert [list(r.output) for r in reqs] == golden
    assert eng.stats.replays == 1


def test_cancelled_request_stays_cancelled_after_restore(served):
    """cancel() must purge snapshot bookkeeping so a rollback cannot
    resurrect (and silently serve) aborted work."""
    cfg, params = served
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 snapshot_every=2)
    a = Request(uid=0, prompt=[5, 9, 2], max_new_tokens=6)
    b = Request(uid=1, prompt=[3, 1, 4], max_new_tokens=6)
    eng.submit(a)
    eng.submit(b)
    eng.step()                      # snapshot@0 captures both as active
    assert eng.cancel(b.uid)
    out_b = list(b.output)
    eng.restore_snapshot()
    eng.run()
    assert b.output == out_b        # never decoded further
    assert all(r.uid != b.uid for r in eng.active.values())
    assert a.output == greedy_reference(cfg, params, a.prompt, 6)


def test_snapshot_rollback_replays_identically(served):
    """Device-fault drill: corrupt decode state, roll back, tokens identical."""
    cfg, params = served
    prompt = [3, 1, 4, 1, 5]
    want = greedy_reference(cfg, params, prompt, 8)

    eng = Engine(cfg, params, capacity=1, max_len=96, prefill_pad=8,
                 snapshot_every=2)
    req = Request(uid=0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    for _ in range(4):
        eng.step()
    # SEU strikes the decode token buffer
    eng.tokens = eng.tokens.at[0].set(123)
    lost = eng.restore_snapshot()   # rollback restores tokens AND req.output
    assert lost >= 0
    eng.run()
    assert req.output == want


# ----------------------- decode-state scrubbing -----------------------------


from repro.core import fault_injection as fi


def _serve_with_scrub(cfg, params, mode, strike=None, strike_at=2):
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 snapshot_every=2, state_scrub=mode)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=6)
            for i, p in enumerate([[5, 9, 2], [3, 1, 4, 1]])]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (eng.queue or eng.active) and steps < 200:
        eng.step()
        steps += 1
        if steps == strike_at and strike is not None:
            strike(eng)
    return [tuple(r.output) for r in reqs], eng


def _hit_tokens(eng):
    eng.tokens = fi.flip_one_bit(eng.tokens, jax.random.key(3))


def _hit_cache(eng):
    eng.cache = fi.inject_pytree_with(eng.cache, jax.random.key(7),
                                      fi.flip_one_bit)


@pytest.mark.parametrize("strike", [_hit_tokens, _hit_cache],
                         ids=["decode_state", "kv_cache"])
def test_state_scrub_rollback_restores_golden_stream(served, strike):
    """A transient SEU in live decode state under ``rollback`` mode: the
    checksum scrub detects it before the next step consumes it, the engine
    rolls back to its verified snapshot, and the final streams are
    bit-identical to a fault-free run."""
    cfg, params = served
    golden, _ = _serve_with_scrub(cfg, params, "off")
    out, eng = _serve_with_scrub(cfg, params, "rollback", strike)
    assert out == golden
    events = eng.drain_state_events()
    assert len(events) == 1 and events[0]["recovered"]
    assert events[0]["seconds"] > 0
    assert int(eng.dependability["faults_detected"]) == 1
    assert int(eng.dependability["faults_recovered"]) == 1
    assert eng.stats.replays == 1


def test_state_scrub_detect_mode_raises_alarm_only(served):
    cfg, params = served
    out, eng = _serve_with_scrub(cfg, params, "detect", _hit_tokens)
    events = eng.drain_state_events()
    assert len(events) == 1 and not events[0]["recovered"]
    assert eng.stats.replays == 0
    assert int(eng.dependability["faults_detected"]) == 1
    assert int(eng.dependability["faults_recovered"]) == 0


def test_state_scrub_clean_run_no_false_positives(served):
    cfg, params = served
    golden, _ = _serve_with_scrub(cfg, params, "off")
    out, eng = _serve_with_scrub(cfg, params, "rollback")
    assert out == golden
    assert eng.drain_state_events() == []
    assert int(eng.dependability["faults_detected"]) == 0
    # the scrub did actually run every step
    assert int(eng.dependability["checks_run"]) > 0


def test_state_scrub_recurrent_family(served):
    """Recurrent caches mutate in place each step (not append-only) — the
    post-mutation re-checksum covers them identically."""
    cfg = reduced(registry.get("rwkv6-1.6b"))
    params = model_api.init_params(cfg, jax.random.key(0))
    golden, _ = _serve_with_scrub(cfg, params, "off")
    out, eng = _serve_with_scrub(cfg, params, "rollback", _hit_cache)
    assert out == golden
    ev = eng.drain_state_events()
    assert len(ev) == 1 and ev[0]["recovered"]


def test_corrupted_snapshot_is_refused(served):
    """If the SEU strikes the golden snapshot itself, restore must refuse
    (checksum mismatch) rather than roll back to corrupted state."""
    cfg, params = served
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 snapshot_every=2, state_scrub="rollback")
    eng.submit(Request(uid=0, prompt=[5, 9, 2], max_new_tokens=6))
    eng.step()
    eng.step()
    assert eng._snapshot is not None
    eng._snapshot["tokens"] = fi.flip_one_bit(eng._snapshot["tokens"],
                                              jax.random.key(1))
    with pytest.raises(RuntimeError, match="snapshot failed checksum"):
        eng.restore_snapshot()


def test_state_scrub_invalid_mode_rejected(served):
    cfg, params = served
    with pytest.raises(ValueError, match="state_scrub"):
        Engine(cfg, params, state_scrub="sometimes")
