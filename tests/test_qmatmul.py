"""qmatmul Pallas kernel vs pure-jnp oracle — shape/dtype/qparam sweeps.

This reproduces the paper's validation methodology (Fig. 4): the kernel
executed under the Pallas interpreter (the stand-in for the HPDP cycle-level
simulator) is numerically compared against an independently implemented
reference, inside a unit-test framework.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant
from repro.kernels.qmatmul.kernel import qmatmul
from repro.kernels.qmatmul.ref import qmatmul_acc_ref, qmatmul_ref
from repro.kernels.qmatmul import ops

jax.config.update("jax_platform_name", "cpu")


def _random_case(rng, m, k, n):
    x_q = jnp.asarray(rng.integers(-128, 128, size=(m, k), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, size=(k, n), dtype=np.int32), jnp.int8)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
    bias = jnp.asarray(rng.integers(-1000, 1000, size=(n,), dtype=np.int32))
    scale = jnp.asarray(rng.uniform(1e-4, 2e-2, size=(n,)).astype(np.float32))
    x_zp = jnp.int32(int(rng.integers(-10, 10)))
    out_zp = jnp.int32(int(rng.integers(-10, 10)))
    return x_q, w_q, colsum, bias, scale, x_zp, out_zp


SHAPES = [
    (8, 16, 8),          # tiny
    (128, 128, 128),     # exactly one block
    (256, 512, 384),     # multi-block all dims
    (1, 4096, 128),      # decode-like (M=1)
    (130, 257, 129),     # ragged — exercises padding/masking
]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_qmatmul_kernel_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 7919 + k * 31 + n)
    x_q, w_q, colsum, bias, scale, x_zp, out_zp = _random_case(rng, m, k, n)
    zps = jnp.stack([x_zp, out_zp])

    got = qmatmul(x_q, w_q, colsum, bias, scale, zps, interpret=True)
    want = qmatmul_ref(x_q, x_zp, w_q, bias, scale, out_zp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 64), (64, 128, 128), (128, 64, 32)])
def test_qmatmul_block_shape_sweep(bm, bn, bk):
    rng = np.random.default_rng(42)
    x_q, w_q, colsum, bias, scale, x_zp, out_zp = _random_case(rng, 96, 160, 96)
    zps = jnp.stack([x_zp, out_zp])
    got = qmatmul(x_q, w_q, colsum, bias, scale, zps,
                  block_m=bm, block_n=bn, block_k=bk, interpret=True)
    want = qmatmul_ref(x_q, x_zp, w_q, bias, scale, out_zp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_qmatmul_acc_int_exact_vs_numpy(seed):
    """int32 accumulator path is exact vs int64 numpy (no hidden float)."""
    rng = np.random.default_rng(seed)
    m, k, n = (int(rng.integers(1, 64)) for _ in range(3))
    x_q, w_q, colsum, bias, scale, x_zp, out_zp = _random_case(rng, m, k, n)
    acc = qmatmul_acc_ref(x_q, x_zp, w_q, bias)
    want = (np.asarray(x_q, np.int64) - int(x_zp)) @ np.asarray(w_q, np.int64) \
        + np.asarray(bias, np.int64)
    np.testing.assert_array_equal(np.asarray(acc, np.int64), want)


def test_qlinear_act_end_to_end_accuracy():
    """float→int8→float round trip approximates the float matmul."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32) * 0.05)
    b = jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * 0.1)

    params = ops.make_qlinear_params(w, b)
    y_f = x @ w + b
    x_scale, x_zp = quant.affine_qparams(jnp.min(x), jnp.max(x))
    o_scale, o_zp = quant.affine_qparams(jnp.min(y_f), jnp.max(y_f))

    y_q = ops.qlinear_act(x, params, x_scale, x_zp, o_scale, o_zp,
                          use_kernel=True, interpret=True)
    rel = np.linalg.norm(np.asarray(y_q - y_f)) / np.linalg.norm(np.asarray(y_f))
    assert rel < 0.02, rel


def test_qlinear_bf16out_matches_float():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32) * 0.02)
    params = ops.make_qlinear_params(w)
    x_scale, x_zp = quant.affine_qparams(jnp.min(x), jnp.max(x))
    y = ops.qlinear_int8_bf16out(x, params, x_scale, x_zp)
    y_f = x @ w
    rel = np.linalg.norm(np.asarray(y - y_f)) / np.linalg.norm(np.asarray(y_f))
    assert rel < 0.02, rel
