"""Per-site policy maps: resolution semantics and the bit-identity
contract.

The invariants that make selective hardening *safe to deploy*:
  * resolution precedence: exact rule > glob rule (declaration order) >
    default; per-call policy overrides beat the map everywhere.
  * a uniform map is bit-for-bit the legacy uniform policy, across
    backends and across both mapped models (transformer FFN, shipdet).
  * mapped forwards on clean data are bit-identical to unmapped forwards
    (exact integer math — hardening must never change answers).
  * ``dependable_matmul_acc`` detects and (ABFT/CKPT/TMR) heals injected
    accumulator faults.
  * the engine's policy-derived storage scrub detects/rolls-back weight
    strikes and stays silent on clean runs.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dependability import Policy, dependable_matmul_acc
from repro.core.policy_map import PolicyMap, PolicyRule, as_policy_map

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- resolve

def test_precedence_exact_over_glob_over_default():
    pm = PolicyMap(rules=(
        PolicyRule("ffn.*", Policy.ABFT),
        PolicyRule("ffn.wd", Policy.TMR),        # exact beats earlier glob
        PolicyRule("ffn.w?", Policy.CKPT),       # later glob: never reached
    ), default=Policy.DMR)
    assert pm.policy_for("ffn.wd") is Policy.TMR
    assert pm.policy_for("ffn.wg") is Policy.ABFT     # first matching glob
    assert pm.policy_for("weights") is Policy.DMR     # default


def test_glob_order_is_declaration_order():
    pm = PolicyMap(rules=(
        PolicyRule("ffn.w?", Policy.CKPT),
        PolicyRule("ffn.*", Policy.ABFT),
    ))
    assert pm.policy_for("ffn.wg") is Policy.CKPT
    assert pm.policy_for("ffn.ws_extra") is Policy.ABFT


def test_rule_backend_falls_back_to_default_backend():
    pm = PolicyMap(rules=(PolicyRule("a", Policy.ABFT, backend="ref"),
                          PolicyRule("b", Policy.ABFT)),
                   default_backend="jnp")
    assert pm.resolve("a") == (Policy.ABFT, "ref")
    assert pm.resolve("b") == (Policy.ABFT, "jnp")


def test_roundtrip_and_coercion(tmp_path):
    pm = PolicyMap(rules=(PolicyRule("ffn.*", Policy.ABFT),
                          PolicyRule("weights", Policy.CKPT)),
                   default=Policy.NONE)
    assert PolicyMap.from_doc(pm.to_doc()) == pm
    assert as_policy_map(pm.dumps()) == pm             # inline JSON text
    p = tmp_path / "map.json"
    pm.save(p)
    assert as_policy_map(str(p)) == pm                 # path
    assert as_policy_map(pm) is pm
    assert as_policy_map(None) is None


def test_uniform_and_scrub_derivation():
    pm = PolicyMap.uniform(Policy.ABFT)
    assert pm.is_uniform() is Policy.ABFT
    assert pm.scrub_mode() == "detect"
    assert pm.storage_policy() is Policy.ABFT
    pm2 = PolicyMap(rules=(PolicyRule("weights", Policy.CKPT),
                           PolicyRule("kv_cache", Policy.CKPT)))
    assert pm2.scrub_mode() == "rollback"
    assert pm2.storage_policy() is Policy.CKPT
    assert PolicyMap.uniform(Policy.NONE).scrub_mode() == "off"


# ------------------------------------------------- dependable_matmul_acc

@pytest.fixture(scope="module")
def mm_operands():
    kx, kw = jax.random.split(jax.random.key(0))
    x = jax.random.randint(kx, (6, 16), -128, 128).astype(jnp.int8)
    w = jax.random.randint(kw, (16, 8), -127, 128).astype(jnp.int8)
    return x, w


@pytest.mark.parametrize("policy", list(Policy))
def test_matmul_acc_clean_bit_identity(mm_operands, policy):
    x, w = mm_operands
    base, _ = dependable_matmul_acc(Policy.NONE, x, w)
    acc, stats = dependable_matmul_acc(policy, x, w)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(base))
    assert int(stats["faults_detected"]) == 0


@pytest.mark.parametrize("policy,heals", [
    (Policy.ABFT, True), (Policy.CKPT, True),
    (Policy.TMR, True), (Policy.DMR, False)])
def test_matmul_acc_detects_and_heals(mm_operands, policy, heals):
    x, w = mm_operands
    base, _ = dependable_matmul_acc(Policy.NONE, x, w)
    inject = lambda acc: acc.at[2, 3].add(1 << 14)      # noqa: E731
    acc, stats = dependable_matmul_acc(policy, x, w, inject=inject)
    assert int(stats["faults_detected"]) == 1
    if heals:
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(base))
    else:       # DMR detect-only: the faulty accumulator ships
        assert np.any(np.asarray(acc) != np.asarray(base))


# ------------------------------------------- mapped transformer forward

@pytest.fixture(scope="module")
def tiny_cfg():
    from repro.configs import registry
    from repro.models.config import reduced
    cfg = reduced(registry.get("smollm-135m"))
    return dataclasses.replace(cfg, quant="w8a8_ffn")


@pytest.fixture(scope="module")
def tiny_model(tiny_cfg):
    from repro.models import api as model_api
    params = model_api.init_params(tiny_cfg, jax.random.key(1))
    tokens = jax.random.randint(jax.random.key(2), (2, 10), 0,
                                tiny_cfg.vocab_size)
    return params, tokens


@pytest.mark.parametrize("policy", [Policy.ABFT, Policy.TMR, Policy.CKPT])
def test_transformer_uniform_map_bit_identical(tiny_cfg, tiny_model, policy):
    from repro.models import api as model_api
    params, tokens = tiny_model
    base = model_api.forward(tiny_cfg, params, tokens).logits
    mapped_cfg = model_api.with_policy_map(
        tiny_cfg, PolicyMap.uniform(policy))
    mapped = model_api.forward(mapped_cfg, params, tokens).logits
    np.testing.assert_array_equal(np.asarray(mapped), np.asarray(base))


def test_transformer_mixed_map_bit_identical(tiny_cfg, tiny_model):
    from repro.models import api as model_api
    params, tokens = tiny_model
    base = model_api.forward(tiny_cfg, params, tokens).logits
    pm = PolicyMap(rules=(PolicyRule("ffn.wg", Policy.ABFT),
                          PolicyRule("ffn.wi", Policy.CKPT),
                          PolicyRule("ffn.wd", Policy.TMR)))
    mapped_cfg = model_api.with_policy_map(tiny_cfg, pm)
    mapped = model_api.forward(mapped_cfg, params, tokens).logits
    np.testing.assert_array_equal(np.asarray(mapped), np.asarray(base))


def test_with_policy_map_validates_backends(tiny_cfg):
    from repro.models import api as model_api
    pm = PolicyMap(rules=(PolicyRule("ffn.wg", Policy.ABFT,
                                     backend="no_such_backend"),))
    with pytest.raises(KeyError):
        model_api.with_policy_map(tiny_cfg, pm)


# ------------------------------------------------------- mapped shipdet

@pytest.fixture(scope="module")
def shipdet_net():
    from repro.models import shipdet
    specs = shipdet.reduced_specs()
    params = shipdet.init_params(specs, jax.random.key(3))
    x = jax.random.uniform(jax.random.key(4), (1, specs[0].h, specs[0].w, 3))
    return shipdet, specs, params, x


@pytest.mark.parametrize("policy", list(Policy))
def test_shipdet_uniform_map_matches_legacy(shipdet_net, policy):
    sd, specs, params, x = shipdet_net
    legacy, _ = sd.forward(specs, params, x, policy=policy,
                           w_checks=sd.deploy_checks(params),
                           golden_wq=sd.golden_weights(params))
    mapped, st = sd.forward(specs, params, x,
                            policy_map=PolicyMap.uniform(policy),
                            w_checks=sd.deploy_checks(params),
                            golden_wq=sd.golden_weights(params))
    np.testing.assert_array_equal(np.asarray(mapped), np.asarray(legacy))


def test_shipdet_mixed_map_bit_identical_and_checked(shipdet_net):
    sd, specs, params, x = shipdet_net
    base, _ = sd.forward(specs, params, x)
    pm = PolicyMap(rules=(PolicyRule("stem", Policy.TMR),
                          PolicyRule("det_head", Policy.CKPT),
                          PolicyRule("conv_*", Policy.ABFT)))
    mapped, st = sd.forward(specs, params, x, policy_map=pm,
                            w_checks=sd.deploy_checks(params),
                            golden_wq=sd.golden_weights(params))
    np.testing.assert_array_equal(np.asarray(mapped), np.asarray(base))
    assert int(st["checks_run"]) > 0


def test_shipdet_rejects_policy_and_map_together(shipdet_net):
    sd, specs, params, x = shipdet_net
    with pytest.raises(ValueError):
        sd.forward(specs, params, x, policy=Policy.ABFT,
                   policy_map=PolicyMap.uniform(Policy.CKPT))


# ------------------------------------------------- engine integration

def test_engine_policy_map_derives_scrubs_and_stays_bit_identical(tiny_cfg):
    from repro.models import api as model_api
    from repro.runtime.serving import Engine, Request

    def serve(eng):
        eng.reset()
        reqs = [Request(uid=i, prompt=[5, 9, 2 + i], max_new_tokens=4)
                for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [tuple(r.output) for r in reqs]

    params = model_api.init_params(tiny_cfg, jax.random.key(5))
    base = Engine(tiny_cfg, params, capacity=2, max_len=48, prefill_pad=8)
    pm = PolicyMap(rules=(PolicyRule("ffn.*", Policy.ABFT),
                          PolicyRule("weights", Policy.CKPT),
                          PolicyRule("kv_cache", Policy.ABFT),
                          PolicyRule("decode_state", Policy.ABFT)))
    mapped = Engine(tiny_cfg, params, capacity=2, max_len=48, prefill_pad=8,
                    policy_map=pm)
    assert mapped.state_scrub == "detect"
    assert mapped.storage_scrub == "rollback"
    assert mapped.storage_scrub_every == mapped.snapshot_every
    assert serve(mapped) == serve(base)
    rep = mapped.dependability_report()
    assert rep["storage_scrub"] == "rollback"


def test_engine_storage_scrub_rollback_recovers_weight_strike(tiny_cfg):
    from repro.core import fault_injection as fi
    from repro.models import api as model_api
    from repro.runtime.serving import Engine, Request
    params = model_api.init_params(tiny_cfg, jax.random.key(6))
    pm = PolicyMap(rules=(PolicyRule("weights", Policy.CKPT),))
    eng = Engine(tiny_cfg, params, capacity=2, max_len=48, prefill_pad=8,
                 policy_map=pm, storage_scrub_every=1)
    golden_out = None
    for strike in (False, True):
        eng.reset()
        reqs = [Request(uid=0, prompt=[5, 9, 2], max_new_tokens=4)]
        eng.submit(reqs[0])
        step = 0
        while (eng.queue or eng.active) and step < 100:
            eng.step()
            step += 1
            if strike and step == 1:
                eng.strike("weights", fi.flip_one_bit, jax.random.key(7))
        if not strike:
            golden_out = tuple(reqs[0].output)
            continue
        events = [e for e in eng.drain_state_events()
                  if e.get("site") == "weights"]
        assert events and events[0]["recovered"]
        assert eng.scrub_storage()          # params restored to golden
        assert tuple(reqs[0].output) == golden_out


def test_engine_storage_scrub_detect_latches_one_alarm(tiny_cfg):
    from repro.core import fault_injection as fi
    from repro.models import api as model_api
    from repro.runtime.serving import Engine, Request
    params = model_api.init_params(tiny_cfg, jax.random.key(8))
    pm = PolicyMap(rules=(PolicyRule("weights", Policy.ABFT),))
    eng = Engine(tiny_cfg, params, capacity=2, max_len=48, prefill_pad=8,
                 policy_map=pm)
    assert eng.storage_scrub == "detect" and eng.storage_scrub_every == 1
    eng.reset()
    r = Request(uid=0, prompt=[5, 9, 2], max_new_tokens=6)
    eng.submit(r)
    step = 0
    while (eng.queue or eng.active) and step < 100:
        eng.step()
        step += 1
        if step == 1:
            eng.strike("weights", fi.flip_one_bit, jax.random.key(9))
    weight_events = [e for e in eng.drain_state_events()
                     if e.get("site") == "weights"]
    assert len(weight_events) == 1          # latched: one strike, one alarm
    assert not weight_events[0]["recovered"]


def test_fleet_accepts_policy_map(tiny_cfg):
    from repro.fleet.fleet import Fleet
    from repro.models import api as model_api
    from repro.runtime.serving import Request
    params = model_api.init_params(tiny_cfg, jax.random.key(10))
    pm = PolicyMap(rules=(PolicyRule("ffn.wg", Policy.ABFT),))
    fleet = Fleet(tiny_cfg, params, n_replicas=2, policy=Policy.ABFT,
                  capacity=2, max_len=48, prefill_pad=8, policy_map=pm)
    try:
        fleet.submit(Request(uid=0, prompt=[5, 9, 2], max_new_tokens=3))
        fleet.run()
        assert 0 in fleet.released
        assert fleet.replicas[0].engine.policy_map == pm
    finally:
        fleet.close()
