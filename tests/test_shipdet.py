"""Ship-detection CNN (the paper's workload): end-to-end quantized inference,
kernel-vs-ref agreement at network level, ABFT policy recovery."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dependability import Policy
from repro.models import shipdet

jax.config.update("jax_platform_name", "cpu")


def _setup():
    specs = shipdet.reduced_specs()
    params = shipdet.init_params(specs, jax.random.key(0))
    x = jax.random.uniform(jax.random.key(1), (1, specs[0].h, specs[0].w, 3))
    return specs, params, x


def test_forward_shapes_and_finite():
    specs, params, x = _setup()
    y, stats = shipdet.forward(specs, params, x)
    assert y.shape[-1] == 6                      # det head channels
    assert np.isfinite(np.asarray(y)).all()


def test_kernel_path_matches_ref_path():
    """Whole-network agreement between Pallas(interpret) and jnp reference —
    the paper's Fig. 4 validation applied end-to-end instead of per-layer."""
    specs, params, x = _setup()
    y_ref, _ = shipdet.forward(specs, params, x, use_kernel=False)
    y_ker, _ = shipdet.forward(specs, params, x, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_ker))


def test_abft_policy_detects_and_recovers():
    specs, params, x = _setup()
    y_clean, stats = shipdet.forward(specs, params, x, policy=Policy.ABFT)
    assert int(stats["checks_run"]) == len(specs)
    assert int(stats["faults_detected"]) == 0

    def inject(acc):
        return acc.at[0, 1, 1, 0].add(jnp.int32(1 << 18))

    y_faulty, stats = shipdet.forward(specs, params, x, policy=Policy.ABFT,
                                      inject=inject)
    assert int(stats["faults_detected"]) >= 1
    np.testing.assert_array_equal(np.asarray(y_faulty), np.asarray(y_clean))


def test_table1_specs_match_paper():
    """Guard: the benchmark layer geometry is exactly the paper's Table 1."""
    t = shipdet.TABLE1_LAYERS
    assert (t[0].cout, t[0].kh, t[0].kw, t[0].cin) == (24, 3, 3, 24)
    assert (t[0].h, t[0].w) == (194, 194)
    assert (t[1].cout, t[1].cin, t[1].h) == (48, 48, 98)
    assert (t[2].cout, t[2].cin, t[2].h) == (96, 96, 50)
    assert (t[3].kh, t[3].kw, t[3].h) == (1, 1, 96)
