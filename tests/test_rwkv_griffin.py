"""RWKV6 + Griffin: chunked/parallel forms vs recurrent oracles, decode
consistency, gradient health."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rwkv6, griffin
from repro.models.config import ArchConfig, RecurrentConfig

jax.config.update("jax_platform_name", "cpu")


def rwkv_cfg(**kw):
    base = dict(name="rwkv-t", family="rwkv", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=1, d_ff=64, vocab_size=128,
                recurrent=RecurrentConfig(kind="rwkv6", head_dim=8),
                compute_dtype="float32", sub_quadratic=True)
    base.update(kw)
    return ArchConfig(**base)


def griffin_cfg(**kw):
    base = dict(name="grif-t", family="hybrid", n_layers=5, d_model=32,
                n_heads=4, n_kv_heads=1, d_ff=64, vocab_size=128, head_dim=8,
                recurrent=RecurrentConfig(kind="rglru", attn_window=8,
                                          lru_width=32, d_conv=4),
                compute_dtype="float32", sub_quadratic=True)
    base.update(kw)
    return ArchConfig(**base)


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,chunk", [(16, 4), (17, 8), (32, 32), (9, 16)])
def test_wkv_chunked_matches_scan(T, chunk):
    rng = np.random.default_rng(T * 31 + chunk)
    B, H, hd = 2, 3, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
               for _ in range(3))
    # decays in a realistic range (0.4 .. 0.999)
    w = jnp.asarray(rng.uniform(0.4, 0.999, size=(B, T, H, hd)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, hd)).astype(np.float32)) * 0.3

    o_ref, s_ref = rwkv6.wkv_scan(r, k, v, w, u)
    o_chk, s_chk = rwkv6.wkv_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_wkv_chunked_with_initial_state():
    rng = np.random.default_rng(0)
    B, T, H, hd = 1, 12, 2, 4
    mk = lambda: jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.5, 0.99, size=(B, T, H, hd)).astype(np.float32))
    u = jnp.zeros((H, hd), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, hd, hd)).astype(np.float32))
    o_ref, s_ref = rwkv6.wkv_scan(r, k, v, w, u, s0)
    o_chk, s_chk = rwkv6.wkv_chunked(r, k, v, w, u, s0, chunk=5)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RWKV6 model
# ---------------------------------------------------------------------------


def test_rwkv_forward_finite():
    cfg = rwkv_cfg()
    params = rwkv6.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    out = rwkv6.forward(cfg, params, tokens)
    assert out.logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(out.logits, np.float32)).all()


def test_rwkv_decode_matches_forward():
    cfg = rwkv_cfg()
    params = rwkv6.init_params(cfg, jax.random.key(0))
    B, S = 2, 10
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full = rwkv6.forward(cfg, params, tokens)
    cache = rwkv6.init_cache(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        logits, cache = rwkv6.decode_step(cfg, params, tokens[:, t], cache)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full.logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_prefill_then_decode():
    cfg = rwkv_cfg()
    params = rwkv6.init_params(cfg, jax.random.key(0))
    B, S = 1, 9
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    full = rwkv6.forward(cfg, params, tokens)
    logits_p, cache = rwkv6.prefill(cfg, params, tokens[:, :S], max_len=S + 2)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1], np.float32),
                               np.asarray(full.logits[:, S - 1], np.float32),
                               rtol=2e-3, atol=2e-3)
    logits_d, _ = rwkv6.decode_step(cfg, params, tokens[:, S], cache)
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(full.logits[:, S], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_grads_finite():
    cfg = rwkv_cfg()
    params = rwkv6.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    (loss, _), grads = jax.value_and_grad(
        lambda p: rwkv6.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for l in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(l, np.float32)).all()


# ---------------------------------------------------------------------------
# Griffin / RG-LRU
# ---------------------------------------------------------------------------


def test_rglru_parallel_matches_step():
    rng = np.random.default_rng(1)
    B, T, W = 2, 11, 16
    x = jnp.asarray(rng.normal(size=(B, T, W)).astype(np.float32))
    g = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, T, W)).astype(np.float32))
    lam = jnp.asarray(rng.uniform(0.5, 3.0, size=(W,)).astype(np.float32))
    h_par = griffin.rglru_parallel(x, g, lam)
    h = jnp.zeros((B, W), jnp.float32)
    seq = []
    for t in range(T):
        h = griffin.rglru_step(x[:, t], g[:, t], lam, h)
        seq.append(h)
    h_seq = jnp.stack(seq, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-5)


def test_griffin_forward_finite():
    cfg = griffin_cfg()
    params = griffin.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    out = griffin.forward(cfg, params, tokens)
    assert out.logits.shape == (2, 12, cfg.vocab_size)
    assert np.isfinite(np.asarray(out.logits, np.float32)).all()


def test_griffin_decode_matches_forward():
    cfg = griffin_cfg()
    params = griffin.init_params(cfg, jax.random.key(0))
    B, S = 1, 12                       # past the window (8)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full = griffin.forward(cfg, params, tokens)
    cache = griffin.init_cache(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        logits, cache = griffin.decode_step(cfg, params, tokens[:, t], cache)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full.logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_griffin_prefill_then_decode():
    cfg = griffin_cfg()
    params = griffin.init_params(cfg, jax.random.key(0))
    B, S = 1, 10
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    full = griffin.forward(cfg, params, tokens)
    logits_p, cache = griffin.prefill(cfg, params, tokens[:, :S], max_len=S + 2)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1], np.float32),
                               np.asarray(full.logits[:, S - 1], np.float32),
                               rtol=2e-3, atol=2e-3)
    logits_d, _ = griffin.decode_step(cfg, params, tokens[:, S], cache)
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(full.logits[:, S], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_griffin_grads_finite():
    cfg = griffin_cfg()
    params = griffin.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    (loss, _), grads = jax.value_and_grad(
        lambda p: griffin.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for l in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(l, np.float32)).all()
