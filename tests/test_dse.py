"""Selective-hardening DSE: genome space, Pareto machinery, cost oracle,
and the campaign-backed evaluator's memoization contract."""
from __future__ import annotations

import random

import jax
import pytest

from repro.dse.fitness import FFN_SITES, Evaluator, Fitness
from repro.dse.search import (
    Candidate, crowding_distance, dominates, non_dominated_sort, pick_best,
    search)
from repro.dse.space import SERVING_SPACE, get_space

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------------------ space

def test_space_roundtrip_and_digest_determinism():
    sp = SERVING_SPACE
    g = sp.uniform_genome("abft")
    assert sp.from_doc(sp.to_doc(g)) == g
    assert sp.from_policy_map(sp.to_policy_map(g)) == g
    assert sp.digest(g) == sp.digest(tuple(g))
    assert sp.digest(g) != sp.digest(sp.uniform_genome("ckpt"))
    assert sp.size() == 3 ** 6          # 3 FFN choices × 3 state choices


def test_space_prunes_unsound_policies():
    sp = SERVING_SPACE
    for site, choices in sp.sites:
        assert "dmr" not in choices
        assert "tmr" not in choices     # XLA CSE collapses in-graph NMR
    # uniform fallback picks the strongest available choice
    g = sp.uniform_genome("tmr")
    assert all(gene == "ckpt" for gene in g)


def test_space_operators_are_seeded_and_valid():
    sp = SERVING_SPACE
    a = sp.random_genome(random.Random(0))
    b = sp.random_genome(random.Random(1))
    assert a == sp.random_genome(random.Random(0))
    child1 = sp.crossover(a, b, random.Random(2))
    child2 = sp.crossover(a, b, random.Random(2))
    assert child1 == child2
    sp.validate(child1)
    sp.validate(sp.mutate(a, random.Random(3), rate=1.0))
    with pytest.raises(ValueError):
        sp.validate(("dmr",) * 6)


def test_shipdet_space_matches_network():
    from repro.models import shipdet
    sp = get_space("shipdet")
    assert sp.site_names == tuple(s.name for s in shipdet.network_specs())
    assert all(len(c) == 5 for _, c in sp.sites)


# ----------------------------------------------------------------- pareto

def _cand(digest, objectives, sdc=0.0, cost=None, uncovered=0):
    cost = objectives[1] if cost is None else cost
    return Candidate(genome=(), digest=digest, fitness=Fitness(
        genes={}, objectives=tuple(objectives), sdc_max=sdc, cost_ms=cost,
        detection_ticks=objectives[2], trials=10, site_rows={},
        uncovered=uncovered))


def test_dominates_and_sort():
    assert dominates((0, 1, 1), (0, 2, 1))
    assert not dominates((0, 1, 1), (0, 1, 1))
    assert not dominates((0, 1, 2), (1, 2, 1))      # trade-off: neither
    cands = [_cand("a", (0.0, 1.0, 1.0)),           # front 0
             _cand("b", (0.0, 2.0, 0.5)),           # front 0 (trade-off)
             _cand("c", (0.0, 2.0, 1.0)),           # dominated by a
             _cand("d", (1.0, 3.0, 2.0))]           # dominated by all
    fronts = non_dominated_sort(cands)
    assert sorted(fronts[0]) == [0, 1]
    assert fronts[1] == [2]
    assert fronts[2] == [3]


def test_crowding_distance_prefers_extremes():
    cands = [_cand(str(i), (0.0, float(i), float(3 - i)))
             for i in range(4)]
    dist = crowding_distance(cands, [0, 1, 2, 3])
    assert dist[0] == float("inf") and dist[3] == float("inf")
    assert dist[1] > 0 and dist[2] > 0


def test_pick_best_is_cheapest_sdc_zero():
    cands = [_cand("cheap_unsafe", (0.3, 0.1, 0.0), sdc=0.3),
             _cand("safe_expensive", (0.1, 2.0, 1.0), sdc=0.0),
             _cand("safe_cheap", (0.1, 1.0, 1.0), sdc=0.0)]
    assert pick_best(cands).digest == "safe_cheap"
    # nothing feasible: lowest SDC wins, then cost
    assert pick_best(cands, sdc_budget=-1).digest == "safe_cheap"
    unsafe_only = [c for c in cands if c.fitness.sdc_max > 0]
    assert pick_best(unsafe_only).digest == "cheap_unsafe"
    assert pick_best([]) is None


def test_pick_best_cost_tie_prefers_coverage():
    # equal cost, equal (zero) observed SDC: the design with no
    # unprotected sites wins even with worse detection latency — lucky
    # small-trial campaigns must not out-rank structural coverage
    cands = [_cand("gap", (0.1, 1.0, 0.2), sdc=0.0, uncovered=1),
             _cand("covered", (0.1, 1.0, 0.9), sdc=0.0, uncovered=0)]
    assert pick_best(cands).digest == "covered"
    # but a strictly cheaper uncovered design still wins the cost objective
    cands.append(_cand("gap_cheaper", (0.1, 0.5, 0.2), sdc=0.0,
                       uncovered=1))
    assert pick_best(cands).digest == "gap_cheaper"


# ------------------------------------------------------------ cost oracle

def _toy_cost_model():
    from repro.dse.cost import CostModel
    site_ms = {"none": 0.0, "abft": 1.0, "dmr": 2.0, "tmr": 3.0,
               "ckpt": 1.5}
    return CostModel({
        "meta": {},
        "serving": {
            "n_layers": 2,
            "sites": {s: {"ms": dict(site_ms)} for s in FFN_SITES},
            "scrub": {"storage_verify_ms": 8.0, "storage_checksum_ms": 1.0},
        },
        "shipdet": {"layers": {
            "stem": {"ms": dict(site_ms)},
            "det_head": {"ms": {k: 2 * v for k, v in site_ms.items()}},
        }},
    })


def test_cost_predict_monotone_and_amortized():
    cm = _toy_cost_model()
    none = cm.predict("serving", {s: "none" for s in SERVING_SPACE.site_names})
    abft = cm.predict("serving",
                      SERVING_SPACE.genes(SERVING_SPACE.uniform_genome("abft")))
    assert none == 0.0 and abft > none
    # CKPT's amortized storage scrub is cheaper than ABFT's every-pump one
    base = {s: "none" for s in SERVING_SPACE.site_names}
    w_abft = cm.predict("serving", {**base, "weights": "abft"})
    w_ckpt = cm.predict("serving", {**base, "weights": "ckpt"})
    assert 0 < w_ckpt < w_abft
    assert cm.predict("shipdet", {"stem": "abft", "det_head": "ckpt"}) \
        == pytest.approx(1.0 + 3.0)
    with pytest.raises(KeyError):
        cm.predict("nope", {})


def test_cost_model_roundtrip(tmp_path):
    from repro.dse.cost import CostModel
    cm = _toy_cost_model()
    p = cm.save(tmp_path / "cm.json")
    assert CostModel.load(p).doc == cm.doc


# ------------------------------------------------ search loop (stubbed)

class _StubEvaluator:
    """Deterministic analytic fitness: no campaigns, instant evaluate."""

    def __init__(self, space, cost_model):
        self.space = space
        self.cm = cost_model
        self.calls = 0

    def evaluate(self, genome):
        self.calls += 1
        genes = self.space.genes(genome)
        unsafe = sum(1 for g in genes.values() if g == "none")
        cost = self.cm.predict(self.space.name, genes)
        return Fitness(genes=genes,
                       objectives=(unsafe / len(genes), cost, 1.0),
                       sdc_max=unsafe / len(genes), cost_ms=cost,
                       detection_ticks=1.0, trials=1, site_rows={})


def test_search_is_deterministic_and_picks_cheapest_safe():
    cm = _toy_cost_model()
    r1 = search(SERVING_SPACE, _StubEvaluator(SERVING_SPACE, cm),
                generations=4, population=10, seed=7)
    r2 = search(SERVING_SPACE, _StubEvaluator(SERVING_SPACE, cm),
                generations=4, population=10, seed=7)
    assert [c.digest for c in r1.front] == [c.digest for c in r2.front]
    assert r1.best.digest == r2.best.digest
    assert r1.best.fitness.sdc_max == 0.0
    # selective hardening must beat the safe uniform corners it was seeded
    # with (abft, ckpt) — the whole point of the search
    sp = SERVING_SPACE
    corners = [cm.predict("serving", sp.genes(sp.uniform_genome(u)))
               for u in ("abft", "ckpt")]
    assert r1.best.fitness.cost_ms < min(corners)
    assert r1.generations == 4 and r1.evaluations == len(r1.archive)
    assert len(r1.history) == 4


# ------------------------------------ evaluator memoization (integration)

def test_evaluator_memoizes_per_site_policy():
    from repro.campaign.stats import SamplingPlan
    cm = _toy_cost_model()
    ev = Evaluator(SERVING_SPACE, cm, seed=0, trials=4,
                   plan=SamplingPlan(chunk=4, min_trials=4))
    g1 = SERVING_SPACE.uniform_genome("abft")
    f1 = ev.evaluate(g1)
    ran_after_first = ev.campaigns_run
    assert ran_after_first == 4          # 3 state sites + 1 kernel row
    assert f1.trials > 0
    # same genome: cached outright; sibling genome sharing genes: no new
    # campaigns for the shared (site, policy) pairs
    assert ev.evaluate(g1) is f1
    g2 = list(g1)
    g2[SERVING_SPACE.site_names.index("weights")] = "ckpt"
    ev.evaluate(tuple(g2))
    assert ev.campaigns_run == ran_after_first + 1      # only weights/ckpt
