"""Dependability layer: inject → detect → recover, property-tested.

System invariants:
  * ABFT detects EVERY single bit flip in the accumulator (exact mod-2^32
    checksums — zero false negatives), and recovery restores the fault-free
    result bit-for-bit.
  * ABFT raises NO false alarms on clean runs (zero false positives).
  * Bitwise 3-way majority corrects any single corrupted replica exactly.
  * SEU injection primitives flip exactly what they claim to flip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import abft, fault_injection as fi, redundancy
from repro.core.dependability import Policy, dependable_qmatmul

jax.config.update("jax_platform_name", "cpu")


def _case(rng, m=32, k=64, n=48):
    x_q = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n), dtype=np.int32), jnp.int8)
    bias = jnp.asarray(rng.integers(-500, 500, (n,), dtype=np.int32))
    x_zp = jnp.int32(3)
    return x_q, w_q, bias, x_zp


# ---------------------------------------------------------------------------
# ABFT
# ---------------------------------------------------------------------------


def test_abft_clean_run_no_false_positives():
    rng = np.random.default_rng(0)
    x_q, w_q, bias, x_zp = _case(rng)
    res = abft.abft_qmatmul(x_q, x_zp, w_q, bias)
    assert bool(res.ok)
    assert int(res.faults_detected) == 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 31))
def test_abft_detects_and_corrects_any_single_bitflip(seed, bit):
    """Exactness property: every (position, bit) flip is detected + corrected."""
    rng = np.random.default_rng(seed)
    x_q, w_q, bias, x_zp = _case(rng, m=8, k=16, n=12)

    clean = abft.abft_qmatmul(x_q, x_zp, w_q, bias)
    r, c = int(rng.integers(0, 8)), int(rng.integers(0, 12))

    def inject(acc):
        return acc.at[r, c].set(acc[r, c] ^ jnp.int32(np.int32(np.uint32(1) << np.uint32(bit))))

    res = abft.abft_qmatmul(x_q, x_zp, w_q, bias, inject=inject)
    assert int(res.faults_detected) >= 1          # detected
    assert bool(res.ok)                           # corrected
    np.testing.assert_array_equal(np.asarray(res.acc), np.asarray(clean.acc))


def test_abft_conv_detects_and_corrects():
    rng = np.random.default_rng(5)
    x_q = jnp.asarray(rng.integers(-128, 128, (1, 10, 10, 8), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (3, 3, 8, 16), dtype=np.int32), jnp.int8)
    bias = jnp.asarray(rng.integers(-100, 100, (16,), dtype=np.int32))
    clean = abft.abft_qconv2d(x_q, jnp.int32(2), w_q, bias)
    assert bool(clean.ok) and int(clean.faults_detected) == 0

    def inject(acc):
        return acc.at[0, 4, 7, 3].add(jnp.int32(1 << 20))

    res = abft.abft_qconv2d(x_q, jnp.int32(2), w_q, bias, inject=inject)
    assert int(res.faults_detected) >= 1
    assert bool(res.ok)
    np.testing.assert_array_equal(np.asarray(res.acc), np.asarray(clean.acc))


def test_abft_overhead_is_small():
    """Checksum FLOPs ≈ matmul/N — structural property of the construction."""
    m, k, n = 128, 256, 128
    matmul_flops = 2 * m * k * n
    checksum_flops = 2 * m * k + m * n   # X·(W1) matvec + rowsum
    assert checksum_flops / matmul_flops < 2.0 / n + 1e-3


# ---------------------------------------------------------------------------
# NMR voting
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_tmr_corrects_single_corrupted_replica(seed):
    rng = np.random.default_rng(seed)
    clean = jnp.asarray(rng.normal(size=(17, 9)).astype(np.float32))
    corrupted = fi.flip_one_bit(clean, jax.random.key(seed))
    # corrupt a different replica each time
    for bad_idx in range(3):
        replicas = [clean, clean, clean]
        replicas[bad_idx] = corrupted
        voted = redundancy.vote(replicas)
        np.testing.assert_array_equal(np.asarray(voted), np.asarray(clean))


def test_dmr_detects_disagreement():
    a = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    b = a.at[1, 2].add(1)
    assert bool(redundancy.agree([a, a]))
    assert not bool(redundancy.agree([a, b]))


def test_dmr_apply_detects_but_returns_replica0():
    f = lambda: jnp.arange(8, dtype=jnp.int32)
    corrupt = lambda y: y.at[3].add(1)
    y, det = redundancy.dmr_apply(f, injectors=(corrupt, None))
    assert bool(det)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(corrupt(f())))
    y, det = redundancy.dmr_apply(f, injectors=(None, None))
    assert not bool(det)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(f()))


def test_storage_checksums_catch_any_single_bitflip():
    """The pytree scrub primitive: exact mod-2^32 detection over mixed
    dtypes, localized to the struck leaf."""
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32, 16)),
                               jnp.float32),
              "b": jnp.arange(-8, 8, dtype=jnp.int8)}
    checks = abft.storage_checksums(params)
    ok = abft.verify_storage(params, checks)
    assert all(bool(v) for v in jax.tree_util.tree_leaves(ok))
    for seed in range(8):
        broken = fi.inject_pytree_with(params, jax.random.key(seed),
                                       fi.flip_one_bit)
        ok = abft.verify_storage(broken, checks)
        assert sum(not bool(v)
                   for v in jax.tree_util.tree_leaves(ok)) == 1, seed


def test_vote_int8_and_bf16_dtypes():
    for dtype in (jnp.int8, jnp.bfloat16, jnp.int32):
        x = jnp.asarray(np.arange(-8, 8), dtype=dtype)
        bad = fi.flip_one_bit(x, jax.random.key(1))
        voted = redundancy.vote([x, bad, x])
        np.testing.assert_array_equal(np.asarray(voted), np.asarray(x))


# ---------------------------------------------------------------------------
# Fault injection primitives
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_flip_one_bit_changes_exactly_one_element(seed):
    x = jnp.zeros((64,), jnp.int32)
    y = fi.flip_one_bit(x, jax.random.key(seed))
    diff = np.asarray(x) != np.asarray(y)
    assert diff.sum() == 1
    # the changed element differs in exactly one bit
    changed = np.asarray(y)[diff][0]
    assert bin(np.uint32(changed)).count("1") == 1


def test_flip_rate_statistics():
    x = jnp.zeros((4096,), jnp.int8)
    y = fi.flip_bits_at_rate(x, jax.random.key(0), rate=0.01)
    flipped_bits = np.unpackbits(np.asarray(y).view(np.uint8)).sum()
    total_bits = 4096 * 8
    # binomial(32768, 0.01): mean 327, std ~18 — accept ±6σ
    assert 200 < flipped_bits < 450


def test_inject_into_pytree():
    params = {"w": jnp.zeros((32, 32), jnp.float32), "b": jnp.zeros((32,), jnp.float32)}
    broken = fi.inject_into_pytree(params, jax.random.key(2), n_flips=1)
    ndiff = sum(int((np.asarray(a) != np.asarray(b)).sum())
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(broken)))
    assert ndiff == 1


# ---------------------------------------------------------------------------
# Policy layer
# ---------------------------------------------------------------------------


def test_dmr_policy_detects_but_does_not_correct():
    """DMR contract: the fault raises the alarm, replica 0's (corrupted)
    output ships unchanged — correction is a failover layer's job."""
    rng = np.random.default_rng(12)
    x_q, w_q, bias, x_zp = _case(rng, m=16, k=32, n=24)
    scale = jnp.full((24,), 1e-3, jnp.float32)

    def inject(acc):
        return acc.at[2, 3].add(jnp.int32(1 << 20))

    y_clean, st = dependable_qmatmul(Policy.DMR, x_q, x_zp, w_q, bias, scale,
                                     jnp.int32(0))
    assert int(st["faults_detected"]) == 0        # no false alarms
    y_faulty, st = dependable_qmatmul(Policy.DMR, x_q, x_zp, w_q, bias, scale,
                                      jnp.int32(0), inject=inject)
    assert int(st["faults_detected"]) == 1
    assert (np.asarray(y_faulty) != np.asarray(y_clean)).any()   # detect-only


@pytest.mark.parametrize("policy", [Policy.NONE, Policy.ABFT, Policy.DMR,
                                    Policy.TMR])
def test_policies_agree_on_clean_input(policy):
    rng = np.random.default_rng(9)
    x_q, w_q, bias, x_zp = _case(rng, m=16, k=32, n=24)
    scale = jnp.full((24,), 1e-3, jnp.float32)
    y, stats = dependable_qmatmul(policy, x_q, x_zp, w_q, bias, scale, jnp.int32(0))
    y_ref, _ = dependable_qmatmul(Policy.NONE, x_q, x_zp, w_q, bias, scale, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_abft_policy_recovers_from_injected_fault():
    rng = np.random.default_rng(10)
    x_q, w_q, bias, x_zp = _case(rng, m=16, k=32, n=24)
    scale = jnp.full((24,), 1e-3, jnp.float32)

    def inject(acc):
        return acc.at[3, 5].add(jnp.int32(1 << 15))

    y_clean, _ = dependable_qmatmul(Policy.ABFT, x_q, x_zp, w_q, bias, scale, jnp.int32(0))
    y_faulty, stats = dependable_qmatmul(Policy.ABFT, x_q, x_zp, w_q, bias, scale,
                                         jnp.int32(0), inject=inject)
    assert int(stats["faults_detected"]) >= 1
    np.testing.assert_array_equal(np.asarray(y_faulty), np.asarray(y_clean))


def test_none_policy_is_vulnerable():
    """Sanity: without dependability, the same fault silently corrupts output."""
    rng = np.random.default_rng(10)
    x_q, w_q, bias, x_zp = _case(rng, m=16, k=32, n=24)
    scale = jnp.full((24,), 1e-3, jnp.float32)

    def inject(acc):
        return acc.at[3, 5].add(jnp.int32(1 << 20))

    y_clean, _ = dependable_qmatmul(Policy.NONE, x_q, x_zp, w_q, bias, scale, jnp.int32(0))
    y_faulty, _ = dependable_qmatmul(Policy.NONE, x_q, x_zp, w_q, bias, scale,
                                     jnp.int32(0), inject=inject)
    assert (np.asarray(y_clean) != np.asarray(y_faulty)).any()


# ---------------------------------------------------------------------------
# CKPT: checksum-detect + rollback-and-reexecute
# ---------------------------------------------------------------------------


def _qm(policy, x_q, w_q, bias, x_zp, **kw):
    from repro.core.dependability import dependable_qmatmul as dq
    n = w_q.shape[1]
    return dq(policy, x_q, x_zp, w_q, bias,
              jnp.full((n,), 1e-3, jnp.float32), jnp.int32(0), **kw)


def test_ckpt_clean_run_no_false_positives():
    rng = np.random.default_rng(0)
    x_q, w_q, bias, x_zp = _case(rng)
    y_none, _ = _qm(Policy.NONE, x_q, w_q, bias, x_zp)
    y, st = _qm(Policy.CKPT, x_q, w_q, bias, x_zp)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_none))
    assert int(st["faults_detected"]) == 0
    assert int(st["faults_recovered"]) == 0
    assert int(st["checks_run"]) == 1


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 31))
def test_ckpt_rolls_back_any_accumulator_bitflip(seed, bit):
    """Exactness property, restart flavor: every (position, bit) flip is
    detected by the checksum and healed by golden re-execution."""
    rng = np.random.default_rng(seed)
    x_q, w_q, bias, x_zp = _case(rng, m=8, k=16, n=12)
    golden, _ = _qm(Policy.NONE, x_q, w_q, bias, x_zp)
    r, c = int(rng.integers(0, 8)), int(rng.integers(0, 12))

    def inject(acc):
        return acc.at[r, c].set(
            acc[r, c] ^ jnp.int32(np.int32(np.uint32(1) << np.uint32(bit))))

    y, st = _qm(Policy.CKPT, x_q, w_q, bias, x_zp, inject=inject)
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_recovered"]) == 1
    np.testing.assert_array_equal(np.asarray(y), np.asarray(golden))


def test_ckpt_heals_weight_seu_with_golden_checkpoint():
    """The CKPT-vs-ABFT separation: a weight-memory SEU is detected by both
    (deploy-time checksum) but only CKPT's rollback to the golden operand
    checkpoint restores the correct output — ABFT's recompute re-executes
    the corrupted storage."""
    rng = np.random.default_rng(1)
    x_q, w_q, bias, x_zp = _case(rng)
    w_check = abft.checksum_vector(w_q)
    golden, _ = _qm(Policy.NONE, x_q, w_q, bias, x_zp)
    w_bad = fi.flip_one_bit(w_q, jax.random.key(2))

    y_ck, st_ck = _qm(Policy.CKPT, x_q, w_bad, bias, x_zp,
                      w_check=w_check, ckpt=(x_q, w_q))
    assert int(st_ck["faults_detected"]) == 1
    assert int(st_ck["faults_recovered"]) == 1
    np.testing.assert_array_equal(np.asarray(y_ck), np.asarray(golden))

    # without a checkpoint the rollback re-executes corrupted storage:
    # detected, NOT recovered — exactly ABFT's limitation
    y_nock, st_nock = _qm(Policy.CKPT, x_q, w_bad, bias, x_zp,
                          w_check=w_check)
    assert int(st_nock["faults_detected"]) == 1
    assert int(st_nock["faults_recovered"]) == 0


def test_ckpt_conv_rollback():
    from repro.core.dependability import dependable_qconv2d
    rng = np.random.default_rng(5)
    x_q = jnp.asarray(rng.integers(-128, 128, (1, 10, 10, 8), dtype=np.int32),
                      jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (3, 3, 8, 16), dtype=np.int32),
                      jnp.int8)
    bias = jnp.asarray(rng.integers(-100, 100, (16,), dtype=np.int32))
    scale = jnp.full((16,), 1e-3, jnp.float32)
    golden, _ = dependable_qconv2d(Policy.NONE, x_q, jnp.int32(2), w_q, bias,
                                   scale, jnp.int32(0))

    def inject(acc):
        return acc.at[0, 4, 7, 3].add(jnp.int32(1 << 20))

    y, st = dependable_qconv2d(Policy.CKPT, x_q, jnp.int32(2), w_q, bias,
                               scale, jnp.int32(0), inject=inject)
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_recovered"]) == 1
    np.testing.assert_array_equal(np.asarray(y), np.asarray(golden))


def test_stats_zero_has_recovered_counter():
    from repro.core.dependability import DependabilityStats
    z = DependabilityStats.zero()
    assert set(z) == {"faults_detected", "faults_corrected",
                      "faults_recovered", "checks_run"}
    merged = DependabilityStats.merge(z, {"faults_recovered": jnp.int32(3)})
    assert int(merged["faults_recovered"]) == 3


# ------------------- dependable_attention (float two-tier) -------------------

from repro.core.dependability import dependable_attention  # noqa: E402


def _attn_inputs(seed=0, B=1, H=2, S=24, hd=16):
    kq, kk, kv = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(kq, (B, H, S, hd)),
            jax.random.normal(kk, (B, H, S, hd)),
            jax.random.normal(kv, (B, H, S, hd)))


def _flip_out_bit(bit, idx=(0, 1, 5, 4)):
    def inj(out):
        bits = jax.lax.bitcast_convert_type(out, jnp.uint32)
        bits = bits.at[idx].set(bits[idx] ^ jnp.uint32(1 << bit))
        return jax.lax.bitcast_convert_type(bits, jnp.float32)
    return inj


@pytest.mark.parametrize("policy", [Policy.NONE, Policy.ABFT, Policy.DMR,
                                    Policy.TMR, Policy.CKPT])
def test_attention_policies_agree_on_clean_input(policy):
    q, k, v = _attn_inputs()
    base, _ = dependable_attention(Policy.NONE, q, k, v)
    out, st = dependable_attention(policy, q, k, v)
    assert bool(jnp.all(out == base))
    assert int(st["faults_detected"]) == 0


@pytest.mark.parametrize("bit", [0, 1, 22, 23, 30, 31])
def test_attention_abft_detects_and_heals_every_output_bit(bit):
    """Both tiers together: high bits trip the float tolerance, low-mantissa
    bits slip under it — the exact output checksum must catch those, and
    row recovery must restore the clean stream bit-for-bit either way."""
    q, k, v = _attn_inputs(1)
    clean, _ = dependable_attention(Policy.NONE, q, k, v)
    out, st = dependable_attention(Policy.ABFT, q, k, v,
                                   inject=_flip_out_bit(bit))
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_corrected"]) == 1
    assert bool(jnp.all(out == clean))


def test_attention_ckpt_rolls_back_whole_op():
    q, k, v = _attn_inputs(2)
    clean, _ = dependable_attention(Policy.NONE, q, k, v)
    out, st = dependable_attention(Policy.CKPT, q, k, v,
                                   inject=_flip_out_bit(0))
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_recovered"]) == 1
    assert int(st["faults_corrected"]) == 0     # rollback, not in-place
    assert bool(jnp.all(out == clean))


def test_attention_dmr_detects_but_ships_replica0():
    q, k, v = _attn_inputs(3)
    clean, _ = dependable_attention(Policy.NONE, q, k, v)
    out, st = dependable_attention(Policy.DMR, q, k, v,
                                   inject=_flip_out_bit(0))
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_corrected"]) == 0
    assert not bool(jnp.all(out == clean))      # faulted replica shipped


def test_attention_tmr_outvotes_corrupted_replica():
    q, k, v = _attn_inputs(4)
    clean, _ = dependable_attention(Policy.NONE, q, k, v)
    out, st = dependable_attention(Policy.TMR, q, k, v,
                                   inject=_flip_out_bit(30))
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_corrected"]) == 1
    assert bool(jnp.all(out == clean))


@pytest.mark.parametrize("backend", ["jnp", "ref", "pallas"])
def test_attention_abft_heals_on_every_backend(backend):
    q, k, v = _attn_inputs(5)
    clean, _ = dependable_attention(Policy.NONE, q, k, v, backend=backend)
    out, st = dependable_attention(Policy.ABFT, q, k, v, backend=backend,
                                   inject=_flip_out_bit(1))
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_corrected"]) == 1
    assert bool(jnp.all(out == clean))


def test_attention_abft_bit_exact_under_jit():
    """Recovery recomputes in the same compilation context, so the healed
    stream must be bit-identical to the same program's clean stream."""
    q, k, v = _attn_inputs(6)

    @jax.jit
    def both(q, k, v):
        clean, _ = dependable_attention(Policy.NONE, q, k, v)
        out, st = dependable_attention(Policy.ABFT, q, k, v,
                                       inject=_flip_out_bit(0))
        return clean, out, st

    clean, out, st = both(q, k, v)
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_corrected"]) == 1
    assert bool(jnp.all(out == clean))


def test_attention_requires_registered_backend():
    from repro.core.backend import Backend
    q, k, v = _attn_inputs(7)
    bare = Backend(name="bare", matmul_acc=None, matmul_acc_checksum=None,
                   conv_acc=None, conv_acc_checksum=None)
    with pytest.raises(ValueError, match="does not register attention"):
        dependable_attention(Policy.ABFT, q, k, v, backend=bare)
