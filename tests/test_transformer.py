"""Unified transformer: forward/grad/decode consistency on reduced configs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm
from repro.models.config import ArchConfig, MoEConfig

jax.config.update("jax_platform_name", "cpu")


def small_dense(**kw) -> ArchConfig:
    # f32 compute: the consistency tests compare two execution orders of the
    # same math, so they must not be at the mercy of bf16 routing near-ties
    base = dict(name="t", family="transformer", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128, head_dim=8,
                compute_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def small_moe(**kw) -> ArchConfig:
    # capacity_factor=8 ⇒ effectively dropless: batch forward and
    # token-by-token decode then agree exactly (capacity drops are a batch-
    # mode effect, so consistency tests must run dropless)
    return small_dense(
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=16, n_shared_experts=1,
                      n_dense_layers=1, capacity_factor=8.0),
        **kw)


def one_device_ctx():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return tfm.ShardCtx(mesh=mesh)


@pytest.mark.parametrize("cfg", [
    small_dense(),
    small_dense(qk_norm=True),
    small_dense(use_bias=True),
    small_dense(swa_window=8),
    small_dense(tie_embeddings=True),
], ids=["plain", "qknorm", "bias", "swa", "tied"])
def test_dense_forward_shapes_and_finite(cfg):
    params = tfm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    out = tfm.forward(cfg, params, tokens)
    assert out.logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(out.logits, np.float32)).all()


def test_moe_forward_single_device():
    cfg = small_moe()
    params = tfm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    out = tfm.forward(cfg, params, tokens)
    assert out.logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(out.logits, np.float32)).all()
    assert float(out.aux_loss) > 0.0


def test_moe_shardmap_matches_single():
    cfg = small_moe()
    params = tfm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    ref = tfm.forward(cfg, params, tokens)
    ctx = one_device_ctx()
    with ctx.mesh:
        got = jax.jit(lambda p, t: tfm.forward(cfg, p, t, ctx))(params, tokens)
    np.testing.assert_allclose(np.asarray(got.logits, np.float32),
                               np.asarray(ref.logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_grad_flows_and_finite():
    cfg = small_moe()
    params = tfm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: tfm.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)
    # routed expert weights must receive gradient (routing is differentiable
    # through gates)
    g = np.asarray(grads["moe_blocks"]["we_i"], np.float32)
    assert np.abs(g).max() > 0


@pytest.mark.parametrize("cfg", [small_dense(), small_dense(swa_window=8),
                                 small_moe()],
                         ids=["dense", "swa", "moe"])
def test_decode_matches_forward(cfg):
    """Teacher-forced decode step-by-step must reproduce forward() logits."""
    params = tfm.init_params(cfg, jax.random.key(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full = tfm.forward(cfg, params, tokens)

    cache = tfm.init_cache(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        logits, cache = tfm.decode_step(cfg, params, tokens[:, t], cache)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full.logits, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_prefill_then_decode_continues_correctly():
    cfg = small_dense()
    params = tfm.init_params(cfg, jax.random.key(0))
    B, S = 2, 10
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)

    # ground truth: forward on S+1 tokens, logits at position S
    full = tfm.forward(cfg, params, tokens)

    logits_p, cache = tfm.prefill(cfg, params, tokens[:, :S], max_len=S + 4)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1], np.float32),
                               np.asarray(full.logits[:, S - 1], np.float32),
                               rtol=3e-2, atol=3e-2)
    logits_d, cache = tfm.decode_step(cfg, params, tokens[:, S], cache)
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(full.logits[:, S], np.float32),
                               rtol=3e-2, atol=3e-2)


def test_swa_ring_buffer_decode_long():
    """Decoding past the window: ring buffer must match forward() with SWA."""
    cfg = small_dense(swa_window=8)
    params = tfm.init_params(cfg, jax.random.key(0))
    B, S = 1, 20                      # > 2× window
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full = tfm.forward(cfg, params, tokens)

    cache = tfm.init_cache(cfg, B, max_len=S)   # ring of size window=8
    assert cache.k.shape[2] == 8
    outs = []
    for t in range(S):
        logits, cache = tfm.decode_step(cfg, params, tokens[:, t], cache)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full.logits, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_embedding_input_mode():
    cfg = small_dense(input_mode="embeddings")
    params = tfm.init_params(cfg, jax.random.key(0))
    embeds = jax.random.normal(jax.random.key(2), (2, 8, cfg.d_model))
    out = tfm.forward(cfg, params, None, embeds=embeds)
    assert out.logits.shape == (2, 8, cfg.vocab_size)
    assert np.isfinite(np.asarray(out.logits, np.float32)).all()
