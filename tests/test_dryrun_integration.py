"""Integration: the launch layer lowers+compiles real configs on a small
fake-device mesh (2×4), including the hillclimb variants.  Runs in a
subprocess so the main pytest process keeps its single-device view."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import registry
from repro.launch import dryrun, mesh as mesh_mod
from repro.models.config import SHAPES
import dataclasses, tempfile, pathlib

mesh = mesh_mod.make_mesh((2, 4))
out = pathlib.Path(tempfile.mkdtemp())

cases = [
    # (arch, shape, overrides) — spans families and perf levers
    ("smollm-135m", "train_4k", {"layout": "dp", "remat": "none"}),
    ("qwen3-0.6b", "decode_32k", {"quant_kv": True}),
    ("mixtral-8x7b", "decode_32k", {"quant": "w8a8_ffn"}),   # expert-TP path
    ("rwkv6-1.6b", "long_500k", {}),
    ("recurrentgemma-2b", "decode_32k", {}),
    ("musicgen-large", "prefill_32k", {}),                   # embeds stub
    ("llama3-405b", "train_4k", {"seq_shard": True, "grad_accum": 2}),
]
# shrink the big ones so an 8-device CPU compile stays fast
shrink = {"n_layers": 2}
for arch, shape_name, ov in cases:
    cfg = registry.get(arch)
    cfg = dataclasses.replace(cfg, **shrink, **ov)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_dense_layers=min(cfg.moe.n_dense_layers, 1)))
    shape = SHAPES[shape_name]
    # shrink shapes too (keep divisibility by mesh axes)
    shape = dataclasses.replace(shape, seq_len=min(shape.seq_len, 2048),
                                global_batch=min(shape.global_batch, 8))
    rec = dryrun.run_cell(cfg, shape, mesh, "2x4", out, verbose=False,
                          save_hlo=False)
    assert rec["hlo_analysis"]["flops"] > 0, (arch, shape_name)
    print("OK", arch, shape_name)
print("DRYRUN_INTEGRATION_OK")
"""


@pytest.mark.slow
@pytest.mark.timeout(1200)
def test_dryrun_small_mesh_all_families():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "DRYRUN_INTEGRATION_OK" in out.stdout
