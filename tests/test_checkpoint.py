"""Checkpoint protocol: atomicity, integrity, retention, elastic restore."""
from __future__ import annotations

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt

jax.config.update("jax_platform_name", "cpu")


def small_state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,))},
        "opt": {"m": jnp.ones((8, 16)) * 0.5},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    state = small_state()
    ckpt.save(tmp_path, 7, state)
    step, restored = ckpt.restore(tmp_path)
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)


def test_latest_step_and_retention(tmp_path):
    state = small_state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, state, keep_n=2)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(d.name for d in Path(tmp_path).iterdir())
    assert kept == ["step_0000000004", "step_0000000005"]


def test_atomicity_orphan_tmp_ignored(tmp_path):
    """A crashed writer leaves step_N.tmp; restore must ignore it."""
    state = small_state()
    ckpt.save(tmp_path, 3, state)
    # simulate a crash mid-write of step 4
    orphan = Path(tmp_path) / "step_0000000004.tmp"
    orphan.mkdir()
    (orphan / "garbage").write_text("crash")
    assert ckpt.latest_step(tmp_path) == 3
    step, _ = ckpt.restore(tmp_path)
    assert step == 3


def test_crc_detects_corruption(tmp_path):
    """The SEU-in-storage threat model: a flipped bit must be caught."""
    state = small_state()
    d = ckpt.save(tmp_path, 1, state)
    shards = d / "shards.npz"
    raw = bytearray(shards.read_bytes())
    raw[len(raw) // 2] ^= 0x40          # flip one bit mid-file
    shards.write_bytes(bytes(raw))
    with pytest.raises((IOError, ValueError, Exception)):
        ckpt.restore(tmp_path, 1)


def test_elastic_restore_new_mesh(tmp_path):
    """Save under a (2,1) mesh layout, restore onto (1,2) — elastic restart."""
    from jax.sharding import PartitionSpec as P
    state = small_state()
    specs = {"params": {"w": P("data", "model"), "b": P("model")},
             "opt": {"m": P("data", "model")}, "step": P()}
    mesh_a = jax.make_mesh((1, 1), ("data", "model"))
    ckpt.save(tmp_path, 5, state, specs=specs)
    mesh_b = jax.make_mesh((1, 1), ("data", "model"))
    step, restored = ckpt.restore(tmp_path, 5, mesh=mesh_b, specs=specs)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path / "nope")


# ---------------------------- property tests --------------------------------

from hypothesis import given, settings, strategies as st


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_checkpoint_roundtrip_property(depth, width, seed):
    """Arbitrary nested pytrees of arbitrary-shape arrays survive
    save→restore bit-exactly (crc verified on the way back in)."""
    import numpy as _np
    import tempfile
    rng = _np.random.default_rng(seed)

    def make(d):
        if d == 0:
            shape = tuple(int(x) for x in rng.integers(1, 5, rng.integers(0, 3)))
            dt = rng.choice([_np.float32, _np.int32, _np.float64])
            return (rng.standard_normal(shape) * 10).astype(dt)
        return {f"k{i}": make(d - 1) for i in range(min(width, 3))}

    state = {"tree": make(depth % 3), "step": _np.int64(seed)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state)
        _, restored = ckpt.restore(d)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), state, restored)
