"""Checkpoint protocol: atomicity, integrity, retention, elastic restore."""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt

jax.config.update("jax_platform_name", "cpu")


def small_state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,))},
        "opt": {"m": jnp.ones((8, 16)) * 0.5},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    state = small_state()
    ckpt.save(tmp_path, 7, state)
    step, restored = ckpt.restore(tmp_path)
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)


def test_latest_step_and_retention(tmp_path):
    state = small_state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, state, keep_n=2)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(d.name for d in Path(tmp_path).iterdir())
    assert kept == ["step_0000000004", "step_0000000005"]


def test_atomicity_orphan_tmp_ignored(tmp_path):
    """A crashed writer leaves step_N.tmp; restore must ignore it."""
    state = small_state()
    ckpt.save(tmp_path, 3, state)
    # simulate a crash mid-write of step 4
    orphan = Path(tmp_path) / "step_0000000004.tmp"
    orphan.mkdir()
    (orphan / "garbage").write_text("crash")
    assert ckpt.latest_step(tmp_path) == 3
    step, _ = ckpt.restore(tmp_path)
    assert step == 3


def test_crc_detects_corruption(tmp_path):
    """The SEU-in-storage threat model: a flipped bit must be caught."""
    state = small_state()
    d = ckpt.save(tmp_path, 1, state)
    shards = d / "shards.npz"
    raw = bytearray(shards.read_bytes())
    raw[len(raw) // 2] ^= 0x40          # flip one bit mid-file
    shards.write_bytes(bytes(raw))
    with pytest.raises((IOError, ValueError, Exception)):
        ckpt.restore(tmp_path, 1)


def test_elastic_restore_new_mesh(tmp_path):
    """Save under a (2,1) mesh layout, restore onto (1,2) — elastic restart."""
    from jax.sharding import PartitionSpec as P
    state = small_state()
    specs = {"params": {"w": P("data", "model"), "b": P("model")},
             "opt": {"m": P("data", "model")}, "step": P()}
    mesh_a = jax.make_mesh((1, 1), ("data", "model"))
    ckpt.save(tmp_path, 5, state, specs=specs)
    mesh_b = jax.make_mesh((1, 1), ("data", "model"))
    step, restored = ckpt.restore(tmp_path, 5, mesh=mesh_b, specs=specs)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path / "nope")


# ---------------------------- property tests --------------------------------

from hypothesis import given, settings, strategies as st


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_checkpoint_roundtrip_property(depth, width, seed):
    """Arbitrary nested pytrees of arbitrary-shape arrays survive
    save→restore bit-exactly (crc verified on the way back in)."""
    import numpy as _np
    import tempfile
    rng = _np.random.default_rng(seed)

    def make(d):
        if d == 0:
            shape = tuple(int(x) for x in rng.integers(1, 5, rng.integers(0, 3)))
            dt = rng.choice([_np.float32, _np.int32, _np.float64])
            return (rng.standard_normal(shape) * 10).astype(dt)
        return {f"k{i}": make(d - 1) for i in range(min(width, 3))}

    state = {"tree": make(depth % 3), "step": _np.int64(seed)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state)
        _, restored = ckpt.restore(d)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), state, restored)


# ------------------- incremental + async checkpointing ----------------------


def _mutate(state, r=-1.0):
    out = jax.tree_util.tree_map(lambda x: x, state)
    out["params"]["w"] = state["params"]["w"].at[0, 0].set(r)
    return out


def test_incremental_restore_bit_identical_to_full(tmp_path):
    """Acceptance: a chained incremental checkpoint restores bit-identically
    to a full checkpoint of the same state."""
    state = small_state()
    state2 = _mutate(state)
    inc_dir, full_dir = tmp_path / "inc", tmp_path / "full"
    with ckpt.IncrementalCheckpointer(inc_dir, async_write=False) as c:
        c.save(1, state)
        c.save(2, state2)
    ckpt.save(full_dir, 2, state2)
    s_inc, r_inc = ckpt.restore(inc_dir)
    s_full, r_full = ckpt.restore(full_dir)
    assert s_inc == s_full == 2
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), r_inc, r_full)


def test_incremental_writes_only_dirty_chunks(tmp_path):
    state = small_state()
    with ckpt.IncrementalCheckpointer(tmp_path, async_write=False,
                                      chunk_bytes=128) as c:
        c.save(1, state)
        first = c.stats["chunks_written"]
        c.save(2, _mutate(state))              # one element changed
        assert c.stats["chunks_written"] == first + 1
        c.save(3, _mutate(state))              # nothing changed since step 2
        assert c.stats["chunks_written"] == first + 1
        assert c.dirty_fraction() < 1.0


def test_async_writer_bounded_staleness_and_durability(tmp_path):
    state = small_state()
    with ckpt.IncrementalCheckpointer(tmp_path, async_write=True,
                                      max_pending=2) as c:
        for s in range(1, 6):
            c.save(s, _mutate(state, float(s)))
        c.wait()
        assert ckpt.latest_step(tmp_path) == 5
    _, restored = ckpt.restore(tmp_path)
    assert float(np.asarray(restored["params"]["w"])[0, 0]) == 5.0


def test_crash_mid_write_restores_last_durable_manifest(tmp_path, monkeypatch):
    """Kill the writer between the data write and the manifest publish: the
    half-written step must be invisible and the previous chain bit-exact."""
    state = small_state()
    state2 = _mutate(state)
    c = ckpt.IncrementalCheckpointer(tmp_path, async_write=False)
    c.save(1, state)

    real_rename = os.rename

    def crash_rename(src, dst):
        raise OSError("simulated power loss before publish")

    monkeypatch.setattr(os, "rename", crash_rename)
    with pytest.raises(OSError):
        c.save(2, state2)
    monkeypatch.setattr(os, "rename", real_rename)

    # the torn write left a .tmp dir at most — never a manifest
    assert ckpt.latest_step(tmp_path) == 1
    step, restored = ckpt.restore(tmp_path)
    assert step == 1
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), state, restored)

    # the writer retries cleanly after the crash (baseline uncorrupted) and
    # the orphaned tmp dir is swept by the successful publish
    c.save(2, state2)
    assert ckpt.latest_step(tmp_path) == 2
    assert not list(Path(tmp_path).glob("*.tmp"))
    _, r2 = ckpt.restore(tmp_path)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), state2, r2)


def test_restore_leaves_partial_matches_full(tmp_path):
    state = small_state()
    ckpt.save(tmp_path / "full", 1, state)              # format 1
    with ckpt.IncrementalCheckpointer(tmp_path / "inc",
                                      async_write=False) as c:
        c.save(1, state)
        c.save(2, _mutate(state))                       # format 2, chained
    for d, ref in ((tmp_path / "full", state),
                   (tmp_path / "inc", _mutate(state))):
        leaves = ckpt.restore_leaves(d, ["params/w", "opt/m"])
        assert set(leaves) == {"params/w", "opt/m"}
        np.testing.assert_array_equal(leaves["params/w"],
                                      np.asarray(ref["params"]["w"]))
        np.testing.assert_array_equal(leaves["opt/m"],
                                      np.asarray(ref["opt"]["m"]))
    # unknown paths are absent, not an error (caller falls back)
    assert ckpt.restore_leaves(tmp_path / "inc", ["no/such"]) == {}


def test_incremental_chunk_crc_detects_storage_seu(tmp_path):
    """Same SEU-in-storage refusal as full checkpoints, per chunk."""
    state = small_state()
    with ckpt.IncrementalCheckpointer(tmp_path, async_write=False) as c:
        c.save(1, state)
    shards = Path(tmp_path) / "step_0000000001" / "chunks.npz"
    raw = bytearray(shards.read_bytes())
    raw[len(raw) // 2] ^= 0x40
    shards.write_bytes(bytes(raw))
    with pytest.raises((IOError, ValueError, Exception)):
        ckpt.restore(tmp_path, 1)


def test_retention_keeps_chain_referenced_dirs(tmp_path):
    """keep_n pruning must never delete a step dir an alive manifest still
    references for clean chunks."""
    state = small_state()
    with ckpt.IncrementalCheckpointer(tmp_path, async_write=False,
                                      keep_n=2) as c:
        for s in range(1, 7):
            c.save(s, _mutate(state, float(s)))
    # steps 5 and 6 are kept; both reference step 1 (the only writer of the
    # never-dirtied leaves), so step 1 must survive
    names = sorted(d.name for d in Path(tmp_path).iterdir())
    assert "step_0000000006" in names and "step_0000000005" in names
    assert "step_0000000001" in names
    _, restored = ckpt.restore(tmp_path)
    assert float(np.asarray(restored["params"]["w"])[0, 0]) == 6.0


def test_async_save_snapshots_before_caller_mutates(tmp_path):
    """save() must capture the state at call time: a numpy leaf mutated by
    the caller after save() returns must not leak into the durable bytes."""
    w = np.zeros((64, 64), np.float32)
    with ckpt.IncrementalCheckpointer(tmp_path, async_write=True) as c:
        c.save(1, {"w": w})
        w[:] = 7.0                       # caller keeps training/serving
        c.wait()
    _, restored = ckpt.restore(tmp_path)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.zeros((64, 64), np.float32))


def test_failed_write_does_not_corrupt_stats_or_rebase(tmp_path, monkeypatch):
    state = small_state()
    c = ckpt.IncrementalCheckpointer(tmp_path, async_write=False,
                                     full_every=2)
    c.save(1, state)
    before = dict(c.stats)
    real_rename = os.rename
    monkeypatch.setattr(os, "rename",
                        lambda s, d: (_ for _ in ()).throw(OSError("torn")))
    with pytest.raises(OSError):
        c.save(2, _mutate(state))
    monkeypatch.setattr(os, "rename", real_rename)
    assert c.stats == before             # nothing counted for the torn write
    c.save(2, _mutate(state))            # durable save #2 → the rebase
    assert c.stats["saves"] == 2
    man = json.loads((Path(tmp_path) / "step_0000000002" /
                      "manifest.json").read_text())
    assert man["rebase"] is True
