"""Data pipeline: determinism, host sharding, prefetch, mmap corpus."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import registry
from repro.data import pipeline as dp
from repro.models.config import ShapeConfig, reduced

SHAPE = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")


def cfg():
    return reduced(registry.get("smollm-135m"))


def test_batch_at_deterministic():
    s1 = dp.TokenStream(cfg(), SHAPE, seed=3, n_hosts=1, host_id=0)
    s2 = dp.TokenStream(cfg(), SHAPE, seed=3, n_hosts=1, host_id=0)
    b1, b2 = s1.batch_at(17), s2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_batches_differ_across_steps_and_hosts():
    s = dp.TokenStream(cfg(), SHAPE, seed=3, n_hosts=2, host_id=0)
    s2 = dp.TokenStream(cfg(), SHAPE, seed=3, n_hosts=2, host_id=1)
    assert not np.array_equal(s.batch_at(0)["tokens"], s.batch_at(1)["tokens"])
    assert not np.array_equal(s.batch_at(0)["tokens"], s2.batch_at(0)["tokens"])


def test_host_sharding_batch_split():
    s = dp.TokenStream(cfg(), SHAPE, seed=0, n_hosts=4, host_id=0)
    assert s.batch_at(0)["tokens"].shape == (2, 32)
    with pytest.raises(ValueError):
        dp.TokenStream(cfg(), SHAPE, seed=0, n_hosts=3, host_id=0)


def test_labels_are_shifted_tokens():
    s = dp.TokenStream(cfg(), SHAPE, seed=1, n_hosts=1, host_id=0)
    b = s.batch_at(0)
    # tokens[t+1] == labels[t] (same underlying window)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab_range():
    c = cfg()
    s = dp.TokenStream(c, SHAPE, seed=5, n_hosts=1, host_id=0)
    b = s.batch_at(123)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < c.vocab_size


def test_prefetch_matches_sync(tmp_path):
    s = dp.TokenStream(cfg(), SHAPE, seed=2, n_hosts=1, host_id=0)
    it = dp.prefetch(s, start_step=5, depth=2)
    for expect_step in (5, 6, 7):
        step, batch = next(it)
        assert step == expect_step
        np.testing.assert_array_equal(batch["tokens"],
                                      s.batch_at(expect_step)["tokens"])
    it.close()


def test_mmap_corpus(tmp_path):
    data = np.arange(10_000, dtype=np.int32) % 97
    path = tmp_path / "corpus.bin"
    data.tofile(path)
    c = cfg()
    corp = dp.MmapCorpus(str(path), c, SHAPE, seed=0)
    b = corp.batch_at(0)
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # deterministic
    b2 = dp.MmapCorpus(str(path), c, SHAPE, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


def test_embeds_for_embedding_archs():
    c = reduced(registry.get("musicgen-large"))
    s = dp.TokenStream(c, SHAPE, seed=0, n_hosts=1, host_id=0)
    b = s.batch_at(0)
    assert b["embeds"].shape == (8, 32, c.d_model)
