"""Campaign engine: statistical coverage claims, determinism, report I/O.

The paper-level invariants the campaign must certify empirically:
  * ABFT detects 100% of single accumulator bit-flips (exact mod-2^32
    checksum — zero false negatives) over hundreds of seeded trials.
  * TMR's bitwise majority vote yields zero SDC for any single-replica
    corruption, at every injection site.
  * A campaign is a pure function of its spec + seed (bit-exact replay).
  * Reports round-trip through JSON.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import (
    CampaignSpec, ConfigResult, build_case, classify_counts, expand_grid,
    load_report, resolve_fault_model, run_campaign, trial_keys, write_report)
from repro.campaign.runner import SUPPORTED
from repro.core import fault_injection as fi
from repro.core.dependability import Policy

jax.config.update("jax_platform_name", "cpu")


def _run_spec(spec: CampaignSpec):
    case = build_case(spec.workload, spec.seed)
    fault = resolve_fault_model(spec.fault_model)
    return case.run_trials(spec.policy, spec.site, fault.apply,
                           trial_keys(spec))


# ---------------------------------------------------------------------------
# (a) ABFT zero-false-negative claim, empirically
# ---------------------------------------------------------------------------


def test_abft_detects_all_accumulator_bitflips_200_trials():
    spec = CampaignSpec("qmatmul", Policy.ABFT, "accumulator",
                        "single_bitflip", trials=200, seed=0)
    detected, mismatch = _run_spec(spec)
    assert detected.shape == (200,)
    assert detected.all(), "ABFT missed an accumulator bit flip"
    assert not mismatch.any(), "ABFT recovery did not restore the golden output"


def test_none_policy_has_nonzero_sdc():
    spec = CampaignSpec("qmatmul", Policy.NONE, "accumulator",
                        "single_bitflip", trials=200, seed=0)
    detected, mismatch = _run_spec(spec)
    assert not detected.any()                     # no detection mechanism
    assert mismatch.any(), "expected some silent corruption under Policy.NONE"


# ---------------------------------------------------------------------------
# (b) TMR corrects any single-replica corruption
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("site", ["accumulator", "weights", "activations"])
def test_tmr_zero_sdc_every_site(site):
    spec = CampaignSpec("qmatmul", Policy.TMR, site, "single_bitflip",
                        trials=100, seed=1)
    detected, mismatch = _run_spec(spec)
    counts = classify_counts(detected, mismatch)
    assert counts["sdc"] == 0
    assert counts["detected_uncorrected"] == 0
    # every manifested fault was voted away
    assert counts["detected_corrected"] + counts["masked"] == 100


# ---------------------------------------------------------------------------
# (b2) DMR: full detection, zero correction — the detect-then-failover half
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("site", ["accumulator", "weights", "activations"])
def test_dmr_detects_every_manifested_fault_but_corrects_none(site):
    spec = CampaignSpec("qmatmul", Policy.DMR, site, "single_bitflip",
                        trials=100, seed=2)
    detected, mismatch = _run_spec(spec)
    counts = classify_counts(detected, mismatch)
    assert counts["sdc"] == 0                      # nothing slips silently
    assert counts["detected_corrected"] == 0       # …but nothing is healed
    assert counts["detected_uncorrected"] > 0
    # detection fires exactly when the fault manifested in the output
    np.testing.assert_array_equal(detected, mismatch)


# ---------------------------------------------------------------------------
# (c) determinism
# ---------------------------------------------------------------------------


def test_trial_classification_deterministic_for_fixed_seed():
    spec = CampaignSpec("qmatmul", Policy.NONE, "accumulator",
                        "single_bitflip", trials=64, seed=7)
    d1, m1 = _run_spec(spec)
    d2, m2 = _run_spec(spec)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(m1, m2)

    other = CampaignSpec("qmatmul", Policy.NONE, "accumulator",
                         "single_bitflip", trials=64, seed=8)
    d3, m3 = _run_spec(other)
    assert not (np.array_equal(m1, m3) and np.array_equal(d1, d3)), \
        "different seeds must draw different faultloads"


def test_trial_keys_differ_across_configurations():
    a = trial_keys(CampaignSpec("qmatmul", Policy.NONE, "accumulator",
                                "single_bitflip", 8, seed=0))
    b = trial_keys(CampaignSpec("qmatmul", Policy.ABFT, "accumulator",
                                "single_bitflip", 8, seed=0))
    assert not np.array_equal(np.asarray(jax.random.key_data(a)),
                              np.asarray(jax.random.key_data(b)))


# ---------------------------------------------------------------------------
# (d) report round-trip
# ---------------------------------------------------------------------------


def test_report_json_round_trip(tmp_path):
    specs = expand_grid(["qmatmul"], [Policy.NONE, Policy.ABFT],
                        ["accumulator"], ["single_bitflip", "stuck_at1"],
                        trials=16, seed=0, supported=SUPPORTED)
    results = run_campaign(specs)
    assert len(results) == 4
    meta = {"seed": 0, "trials_per_config": 16}
    jpath, mpath = write_report(results, tmp_path, meta)
    meta2, results2 = load_report(jpath)
    assert meta2["seed"] == 0
    assert results2 == list(results)
    # derived rates survive (recomputed from counts, not stored state)
    for orig, rt in zip(results, results2):
        assert rt.detection_rate == orig.detection_rate
        assert rt.coverage == orig.coverage
    assert "| workload |" in mpath.read_text()


def test_config_result_rates():
    r = ConfigResult("w", "none", "s", "m", trials=10, masked=4,
                     detected_corrected=3, detected_uncorrected=1, sdc=2)
    assert r.detection_rate == pytest.approx(0.4)
    assert r.sdc_rate == pytest.approx(0.2)
    assert r.coverage == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# new core primitive: stuck-at
# ---------------------------------------------------------------------------


def test_stuck_at_forces_single_bit():
    x = jnp.zeros((128,), jnp.int32)
    y1 = fi.stuck_at(x, jax.random.key(0), 1)       # stuck-at-1 on zeros: flips
    diff = np.asarray(y1) != 0
    assert diff.sum() == 1
    assert bin(np.uint32(np.asarray(y1)[diff][0])).count("1") == 1
    y0 = fi.stuck_at(x, jax.random.key(0), 0)       # stuck-at-0 on zeros: masked
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(x))


def test_stuck_at_intrinsic_masking_in_campaign():
    """Stuck-at faultloads must show the ~50% masking floor (bit already at
    the stuck value) that distinguishes them from XOR flips."""
    spec = CampaignSpec("qmatmul", Policy.ABFT, "accumulator", "stuck_at1",
                        trials=200, seed=3)
    detected, _ = _run_spec(spec)
    rate = detected.mean()
    assert 0.25 < rate < 0.95, rate     # XOR flips would give exactly 1.0


# ---------------------------------------------------------------------------
# per-bit-position accumulator coverage
# ---------------------------------------------------------------------------


def test_bit_sweep_separates_masked_and_detected_bits():
    """The bit table's two regimes: requantization (scale 1e-3) rounds away
    low accumulator bits, the sign bit always corrupts silently under NONE,
    and ABFT detects the targeted flip at *every* bit position."""
    from repro.campaign.runner import ACC_BITS, run_bit_sweep
    rows = run_bit_sweep("qmatmul", [Policy.NONE, Policy.ABFT],
                         trials_per_bit=4, seed=0)
    assert len(rows) == 2 * ACC_BITS
    none = {r.bit: r for r in rows if r.policy == "none"}
    abft = {r.bit: r for r in rows if r.policy == "abft"}
    assert none[0].masked == 4 and none[0].sdc == 0      # ±1 rounds away
    assert none[31].sdc == 4                             # sign flip: SDC
    assert all(r.detection_rate == 1.0 for r in abft.values())
    assert all(r.sdc == 0 for r in abft.values())


def test_bit_sweep_rejects_model_workloads():
    """The error must name the supported kernel workloads, not leak
    internals — it is the user's cue for what --workload to pass."""
    from repro.campaign.runner import kernel_workloads, run_bit_sweep
    with pytest.raises(ValueError) as ei:
        run_bit_sweep("transformer", [Policy.NONE], trials_per_bit=1)
    msg = str(ei.value)
    assert "'transformer'" in msg
    for w in kernel_workloads():
        assert w in msg
    assert kernel_workloads() == ["flashattn", "qconv2d", "qmatmul"]
    with pytest.raises(KeyError, match="unknown workload"):
        run_bit_sweep("nope", [Policy.NONE], trials_per_bit=1)


def test_backend_axis_in_grid_and_report(tmp_path):
    """One sweep over two backends: rows carry the backend, labels (and so
    the trial key streams) stay unchanged for the default backend."""
    specs = expand_grid(["qmatmul"], [Policy.ABFT], ["accumulator"],
                        ["single_bitflip"], trials=8, seed=0,
                        supported=SUPPORTED, backends=["jnp", "pallas"])
    assert [s.backend for s in specs] == ["jnp", "pallas"]
    assert specs[0].label() == "qmatmul/abft/accumulator/single_bitflip"
    assert specs[1].label().endswith("/pallas")
    results = run_campaign(specs)
    assert {r.backend for r in results} == {"jnp", "pallas"}
    assert all(r.detection_rate == 1.0 for r in results)
    jpath, _ = write_report(results, tmp_path, {"seed": 0})
    _, rt = load_report(jpath)
    assert [r.backend for r in rt] == ["jnp", "pallas"]


# ---------------------------------------------------------------------------
# CLI end-to-end
# ---------------------------------------------------------------------------


def test_cli_writes_reports(tmp_path, capsys):
    import json

    from repro.campaign import cli
    rc = cli.main([
        "--workload", "qmatmul", "--policies", "none,abft",
        "--sites", "accumulator", "--fault-models", "single_bitflip",
        "--trials", "32", "--bit-trials", "2", "--seed", "0",
        "--out", str(tmp_path), "--quiet"])
    assert rc == 0
    meta, results = load_report(tmp_path / "campaign.json")
    assert meta["configurations"] == 2
    assert meta["backends"] == "jnp"
    abft = [r for r in results if r.policy == "abft"][0]
    none = [r for r in results if r.policy == "none"][0]
    assert abft.detection_rate == 1.0
    assert none.sdc_rate > 0.0
    md = (tmp_path / "campaign.md").read_text()
    assert "Accumulator bit-position coverage" in md
    bits = json.loads((tmp_path / "campaign.json").read_text())["bit_coverage"]
    assert len(bits) == 2 * 32            # two policies × 32 int32 bits
    assert {b["policy"] for b in bits} == {"none", "abft"}


# ---------------------------------------------------------------------------
# (e) CKPT policy axis + recovery columns
# ---------------------------------------------------------------------------


def test_ckpt_detects_and_recovers_all_accumulator_bitflips():
    spec = CampaignSpec("qmatmul", Policy.CKPT, "accumulator",
                        "single_bitflip", trials=200, seed=0)
    detected, mismatch = _run_spec(spec)
    assert detected.all(), "CKPT checksum missed an accumulator bit flip"
    assert not mismatch.any(), "CKPT rollback did not restore golden output"


def test_ckpt_heals_weight_site_where_abft_cannot():
    """The policy separation the recovery PR exists for: weight-memory SEUs
    end detected_uncorrected under ABFT but detected_corrected under CKPT
    (rollback to the golden operand checkpoint)."""
    ck = classify_counts(*_run_spec(CampaignSpec(
        "qmatmul", Policy.CKPT, "weights", "single_bitflip", 50, seed=0)))
    ab = classify_counts(*_run_spec(CampaignSpec(
        "qmatmul", Policy.ABFT, "weights", "single_bitflip", 50, seed=0)))
    assert ck["sdc"] == 0 and ab["sdc"] == 0           # both covered
    assert ck["detected_corrected"] == 50              # …but only CKPT heals
    assert ab["detected_uncorrected"] == 50


def test_ckpt_activations_blind_spot_is_honest():
    """No checksum covers the op's input contract — CKPT inherits ABFT's
    activations blind spot rather than claiming false coverage."""
    counts = classify_counts(*_run_spec(CampaignSpec(
        "qmatmul", Policy.CKPT, "activations", "single_bitflip", 50, seed=0)))
    assert counts["detected_corrected"] == 0
    assert counts["sdc"] > 0


def test_recovery_columns_in_report(tmp_path):
    specs = expand_grid(["qmatmul"], [Policy.CKPT], ["accumulator"],
                        ["single_bitflip"], trials=16, seed=0,
                        supported=SUPPORTED)
    results = run_campaign(specs)
    assert len(results) == 1
    r = results[0]
    assert r.faults_recovered == r.detected_corrected == 16
    jpath, mpath = write_report(results, tmp_path, {"seed": 0})
    _, rt = load_report(jpath)
    assert rt[0].faults_recovered == 16
    assert "recovered" in mpath.read_text()


def test_serving_ckpt_zero_sdc_with_measured_recovery():
    """Engine-level acceptance slice: CKPT serving trials end with zero SDC,
    nonzero recoveries, and a populated recovery-latency column."""
    specs = expand_grid(["serving"], [Policy.CKPT],
                        ["weights", "decode_state"], ["single_bitflip"],
                        trials=10, seed=0, supported=SUPPORTED)
    results = run_campaign(specs)
    assert len(results) == 2
    for r in results:
        assert r.sdc == 0
        assert r.faults_recovered > 0
        assert r.recovery_ms_mean > 0.0


def test_expanded_sites_registry():
    from repro.campaign import faultload as fl
    assert "kv_cache" in fl.SITES and "decode_state" in fl.SITES
    # kernel workloads silently skip the engine-only sites
    specs = expand_grid(["qmatmul"], [Policy.CKPT], ["kv_cache"],
                        ["single_bitflip"], trials=2, seed=0,
                        supported=SUPPORTED)
    assert run_campaign(specs) == []


# ---------------------------------------------------------------------------
# (g) shipdet deploy-time weight checks (model-level w_check path)
# ---------------------------------------------------------------------------


def test_shipdet_weights_site_covered_by_deploy_checks():
    """Model-level weight-SEU coverage via shipped checksums
    (shipdet.deploy_checks): ABFT layers verify live weights against the
    deploy-time values (detect, zero SDC), CKPT layers additionally roll
    back to the golden weights (heal)."""
    case = build_case("shipdet", 0)
    fault = resolve_fault_model("single_bitflip")

    spec = CampaignSpec("shipdet", Policy.ABFT, "weights",
                        "single_bitflip", trials=20, seed=0)
    det, mis = case.run_trials(Policy.ABFT, "weights", fault.apply,
                               trial_keys(spec))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    # every flip that manifested in the output was detected
    assert counts["detected_uncorrected"] + counts["detected_corrected"] > 0
    assert not np.logical_and(~det, mis).any()

    spec = CampaignSpec("shipdet", Policy.CKPT, "weights",
                        "single_bitflip", trials=20, seed=0)
    det, mis = case.run_trials(Policy.CKPT, "weights", fault.apply,
                               trial_keys(spec))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_uncorrected"] == 0     # rollback healed them all
    assert counts["detected_corrected"] > 0

    spec = CampaignSpec("shipdet", Policy.NONE, "weights",
                        "single_bitflip", trials=20, seed=0)
    det, mis = case.run_trials(Policy.NONE, "weights", fault.apply,
                               trial_keys(spec))
    assert classify_counts(det, mis)["sdc"] > 0    # undefended baseline


# ---------------------------------------------------------------------------
# (i) the float attention workload + the int8-KV serving workload
# ---------------------------------------------------------------------------


def test_flashattn_abft_detects_all_output_bitflips():
    """The decode-stack acceptance bar: every single-bit flip of the
    attention kernel's emitted output is detected (exact bit-checksum tier)
    and healed (SDC = 0) under ABFT."""
    spec = CampaignSpec("flashattn", Policy.ABFT, "accumulator",
                        "single_bitflip", trials=60, seed=0)
    detected, mismatch = _run_spec(spec)
    assert detected.all(), "flashattn ABFT missed an output bit flip"
    assert not mismatch.any(), "flashattn ABFT recovery left a corrupt row"


def test_flashattn_none_policy_has_nonzero_sdc():
    spec = CampaignSpec("flashattn", Policy.NONE, "accumulator",
                        "single_bitflip", trials=60, seed=0)
    detected, mismatch = _run_spec(spec)
    assert not detected.any()
    assert mismatch.any()                       # undefended kernel corrupts


def test_flashattn_tmr_covers_operand_site():
    spec = CampaignSpec("flashattn", Policy.TMR, "activations",
                        "single_bitflip", trials=30, seed=1)
    detected, mismatch = _run_spec(spec)
    counts = classify_counts(detected, mismatch)
    assert counts["sdc"] == 0


def test_serving_int8kv_scrub_covers_kv_cache():
    """Quantizing the KV cache must not narrow the dependability envelope:
    the dtype-uniform state scrub detects kv_cache strikes on the int8
    cache (ABFT) and snapshot rollback heals them (CKPT, SDC = 0)."""
    from repro.campaign.runner import build_case as _bc
    case = _bc("serving_int8kv", seed=0)
    assert case.cfg.quant_kv
    fault = resolve_fault_model("single_bitflip")
    for policy in (Policy.ABFT, Policy.CKPT):
        spec = CampaignSpec("serving_int8kv", policy, "kv_cache",
                            "single_bitflip", trials=4, seed=0)
        detected, mismatch = case.run_trials(policy, "kv_cache", fault.apply,
                                             trial_keys(spec))
        assert detected.all(), f"{policy} missed an int8 kv_cache strike"
        if policy == Policy.CKPT:
            assert not mismatch.any(), "CKPT rollback left a corrupt stream"


def test_table1_bitsweep_report_round_trips():
    """Regression for the committed Table-1 conv bit-sweep artifact
    (``benchmarks/table1_conv.py --bit-sweep``): the report must load
    through the standard loader, round-trip its bit-coverage rows exactly,
    and preserve the headline result — zero residual SDC under abft at
    every accumulator bit of both Table-1 layer geometries."""
    import json
    import pathlib
    from repro.campaign.report import bit_coverage_from_json_dict
    jpath = pathlib.Path(__file__).parent.parent / "reports" / \
        "table1_bitsweep" / "table1_bitsweep.json"
    if not jpath.exists():
        pytest.skip("reports/table1_bitsweep not generated in this checkout")
    raw = json.loads(jpath.read_text())
    meta, results = load_report(jpath)
    assert results == [] and meta["bench"] == "table1_bitsweep"
    rows = bit_coverage_from_json_dict(raw)
    assert [r.to_dict() for r in rows] == raw["bit_coverage"]
    by_cfg = {}
    for r in rows:
        by_cfg.setdefault((r.workload, r.policy), []).append(r)
    assert set(by_cfg) == {
        (wl, pol)
        for wl in ("qconv2d_t1_conv1", "qconv2d_t1_conv4")
        for pol in ("none", "abft")}
    for (wl, pol), cfg_rows in by_cfg.items():
        assert sorted(r.bit for r in cfg_rows) == list(range(32))
        assert all(r.trials > 0 for r in cfg_rows)
        if pol == "abft":
            assert sum(r.sdc for r in cfg_rows) == 0
    # the none rows are what abft is protecting against: the sweep must
    # actually have produced silent corruptions somewhere to be meaningful
    assert sum(r.sdc for r in rows if r.policy == "none") > 0
