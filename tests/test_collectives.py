"""Collective helpers (ring all-gather, reduce-scatter, bf16 grad compression)
vs their XLA-native equivalents, on 8 fake devices in a subprocess."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.parallel import collectives as coll

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 4 * 6, dtype=jnp.float32).reshape(8 * 4, 6)

# ring all-gather == native all-gather (every shard holds the full array,
# P() output = replicated)
ring_full = shard_map(lambda s: coll.ring_all_gather(s, "x", axis=0),
                      mesh=mesh, in_specs=P("x"), out_specs=P(),
                      check_vma=False)
native = shard_map(lambda s: jax.lax.all_gather(s, "x", axis=0, tiled=True),
                   mesh=mesh, in_specs=P("x"), out_specs=P(),
                   check_vma=False)
np.testing.assert_allclose(np.asarray(ring_full(x)), np.asarray(native(x)))
np.testing.assert_allclose(np.asarray(ring_full(x)), np.asarray(x))
print("RING_OK")

# reduce-scatter: sum over axis then scatter == psum sliced
rs = shard_map(lambda s: coll.reduce_scatter(s, "x", axis=0),
               mesh=mesh, in_specs=P(None), out_specs=P("x"),
               check_vma=False)(x)
np.testing.assert_allclose(np.asarray(rs), np.asarray(x) * 8)
print("RS_OK")

# bf16 grad compression: psum in bf16, correct up to bf16 rounding
g = {"w": jnp.ones((8, 4)) * 0.1}
out = shard_map(lambda t: coll.grad_allreduce_bf16(t, "x"),
                mesh=mesh, in_specs=(P(),), out_specs=P(),
                check_vma=False)(g)
np.testing.assert_allclose(np.asarray(out["w"]), 0.8, rtol=2e-2)
assert out["w"].dtype == g["w"].dtype
print("GRADBF16_OK")
"""


@pytest.mark.slow
def test_collectives_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    for tag in ("RING_OK", "RS_OK", "GRADBF16_OK"):
        assert tag in out.stdout
