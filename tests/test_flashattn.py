"""Flash attention Pallas kernel (interpret mode) vs materialized oracle.

Same validation methodology as the paper (Fig. 4): kernel-under-interpreter
compared against an independent reference across shape/dtype/GQA/window
sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flashattn.kernel import flash_attention
from repro.kernels.flashattn.ops import flash_attn
from repro.kernels.flashattn.ref import attention_ref

jax.config.update("jax_platform_name", "cpu")


def qkv(key, B, H, KV, S, hd, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, S, hd), dtype)
    k = jax.random.normal(k2, (B, KV, S, hd), dtype)
    v = jax.random.normal(k3, (B, KV, S, hd), dtype)
    return q, k, v


CASES = [
    # B, H, KV, S, hd, window
    (1, 2, 2, 128, 32, None),          # one block exactly
    (2, 4, 2, 256, 64, None),          # GQA 2:1, multi-block
    (1, 4, 1, 96, 16, None),           # MQA, ragged S < block
    (1, 2, 2, 200, 32, None),          # ragged S, multi-block
    (1, 4, 2, 256, 32, 64),            # sliding window
    (1, 2, 1, 160, 32, 32),            # window smaller than block
]


@pytest.mark.parametrize("B,H,KV,S,hd,window", CASES)
def test_flash_matches_ref(B, H, KV, S, hd, window):
    q, k, v = qkv(jax.random.key(0), B, H, KV, S, hd)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_io():
    q, k, v = qkv(jax.random.key(1), 1, 2, 2, 128, 32, jnp.bfloat16)
    got = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    want = attention_ref(q, k, v)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_noncausal():
    q, k, v = qkv(jax.random.key(2), 1, 2, 2, 128, 32)
    got = flash_attention(q, k, v, causal=False, interpret=True,
                          block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ops_layout_adapter():
    """(B,S,H,hd) wrapper agrees with the model-layout reference."""
    B, S, H, KV, hd = 2, 96, 4, 2, 16
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    got = flash_attn(q, k, v, interpret=True)
    want = jnp.swapaxes(attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)), 1, 2)
    assert got.shape == (B, S, H, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_block_shape_independence():
    """Different BlockSpec tilings must give identical results."""
    q, k, v = qkv(jax.random.key(4), 1, 2, 2, 256, 32)
    a = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    b = flash_attention(q, k, v, interpret=True, block_q=128, block_k=64)
    c = flash_attention(q, k, v, interpret=True, block_q=64, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-6, atol=1e-6)


# --------------------------- backward kernels --------------------------------

from repro.kernels.flashattn.kernel import (     # noqa: E402
    flash_attention_bwd, flash_attention_fwd_lse)
from repro.kernels.flashattn.ops import flash_attn_diff  # noqa: E402

BWD_CASES = [
    # B, H, KV, S, hd, window
    (1, 2, 2, 128, 32, None),
    (1, 4, 2, 128, 16, None),          # GQA 2:1 — head-group accumulation
    (1, 4, 1, 96, 16, None),           # MQA, ragged S
    (1, 2, 2, 192, 32, 64),            # sliding window
]


@pytest.mark.parametrize("B,H,KV,S,hd,window", BWD_CASES)
def test_flash_bwd_matches_ref_grads(B, H, KV, S, hd, window):
    q, k, v = qkv(jax.random.key(7), B, H, KV, S, hd)
    dout = jax.random.normal(jax.random.key(8), (B, H, S, hd))

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True, window=window)
                       * dout)

    def f_flash(q, k, v):
        return jnp.sum(flash_attn_diff(q, k, v, True, window, 64, 64, True)
                       * dout)

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g_fl, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"d{name} mismatch")


def test_flash_fwd_lse_matches_plain_fwd():
    q, k, v = qkv(jax.random.key(9), 1, 2, 2, 128, 32)
    o1 = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    o2, lse = flash_attention_fwd_lse(q, k, v, interpret=True,
                                      block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-6, atol=1e-6)
    # lse is the true logsumexp of masked scores
    import math as _math
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / _math.sqrt(32)
    mask = jnp.tril(jnp.ones((128, 128), bool))
    s = jnp.where(mask, s, -1e30)
    want = jax.nn.logsumexp(s, axis=-1).reshape(1, 2, 128)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ----------------------- checked (two-tier ABFT) kernel ----------------------

from repro.core import abft                      # noqa: E402
from repro.kernels.flashattn.kernel import (     # noqa: E402
    flash_attention_checked)
from repro.kernels.flashattn.ops import flash_attn_model  # noqa: E402

CHECKED_CASES = [
    # B, H, KV, S, hd, window
    (1, 2, 2, 128, 32, None),
    (1, 4, 2, 200, 16, None),          # GQA, ragged S
    (1, 2, 1, 160, 32, 32),            # MQA + sliding window
]


@pytest.mark.parametrize("B,H,KV,S,hd,window", CHECKED_CASES)
def test_checked_kernel_two_tier_outputs(B, H, KV, S, hd, window):
    """The checked kernel must (a) emit the plain kernel's output
    bit-for-bit — recovery recomputes from the plain path, so any drift
    would turn every correction into a false mismatch — (b) carry a float
    check column equal to rowsum_hd(out) up to roundoff, and (c) emit the
    exact mod-2^32 bit checksum ``abft.output_row_checksums`` recomputes."""
    q, k, v = qkv(jax.random.key(11), B, H, KV, S, hd)
    plain = flash_attention(q, k, v, causal=True, window=window,
                            block_q=64, block_k=64, interpret=True)
    out, check, csum = flash_attention_checked(
        q, k, v, causal=True, window=window, block_q=64, block_k=64,
        interpret=True)
    assert out.shape == (B, H, S, hd)
    assert check.shape == csum.shape == (B, H, S)
    assert csum.dtype == jnp.uint32
    assert bool(jnp.all(out == plain))                       # (a) bit-exact
    np.testing.assert_allclose(                              # (b) float tier
        np.asarray(jnp.sum(out, axis=-1)), np.asarray(check),
        rtol=1e-4, atol=1e-4)
    assert bool(jnp.all(abft.output_row_checksums(out) == csum))   # (c)


def test_checked_kernel_bf16_checksum_is_exact():
    q, k, v = qkv(jax.random.key(12), 1, 2, 2, 128, 32, jnp.bfloat16)
    out, check, csum = flash_attention_checked(q, k, v, block_q=64,
                                               block_k=64, interpret=True)
    assert out.dtype == jnp.bfloat16
    assert bool(jnp.all(abft.output_row_checksums(out) == csum))


def test_output_bit_checksum_detects_every_flip():
    """The exact tier's reason to exist: a *lowest-mantissa* flip is far
    below any float tolerance, yet the bit checksum must still flag the
    row — and only that row."""
    q, k, v = qkv(jax.random.key(13), 1, 2, 2, 128, 32)
    out, check, csum = flash_attention_checked(q, k, v, block_q=64,
                                               block_k=64, interpret=True)
    for bit in (0, 12, 23, 31):                  # mantissa → sign sweep
        bits = jax.lax.bitcast_convert_type(out, jnp.uint32)
        bits = bits.at[0, 1, 77, 5].set(bits[0, 1, 77, 5] ^ jnp.uint32(1 << bit))
        bad = jax.lax.bitcast_convert_type(bits, jnp.float32)
        row_ok = abft.output_row_checksums(bad) == csum
        assert not bool(row_ok[0, 1, 77]), f"bit {bit} escaped"
        assert int(jnp.sum(~row_ok)) == 1, f"bit {bit} flagged extra rows"


@pytest.mark.parametrize("S", [5, 37, 100])
def test_flash_attn_model_ragged_small_S(S):
    """flash_attn_model clamps block sizes with ``min(block_q, S)``: model
    layouts shorter than the default 128 block (short prefills) must still
    match the reference, forward and backward."""
    B, H, KV, hd = 1, 2, 2, 16
    ks = jax.random.split(jax.random.key(14), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    dout = jax.random.normal(ks[3], (B, S, H, hd))

    got = flash_attn_model(q, k, v, interpret=True)
    want = jnp.swapaxes(attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2)), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    def f_model(q, k, v):
        return jnp.sum(flash_attn_model(q, k, v, interpret=True) * dout)

    def f_ref(q, k, v):
        return jnp.sum(jnp.swapaxes(attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2)), 1, 2) * dout)

    g_model = jax.grad(f_model, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g_model, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"d{name} mismatch (S={S})")
