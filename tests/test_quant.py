"""Property tests for the quantization core (hypothesis-driven).

Invariants under test:
  * quantize/dequantize round-trip error is bounded by scale/2 inside range
  * zero is exactly representable (required for zp-padding correctness)
  * fp32 requantization agrees with the gemmlowp integer-exact oracle except
    (at most) off-by-one on 0.5-ULP ties, at a tiny rate
  * fake_quant is idempotent and its STE gradient masks saturated entries
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant

jax.config.update("jax_platform_name", "cpu")


@st.composite
def float_arrays(draw, max_dim=64):
    n = draw(st.integers(1, max_dim))
    lo = draw(st.floats(-100.0, 0.0))
    hi = draw(st.floats(0.001, 100.0))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=(n,)).astype(np.float32)


@settings(max_examples=50, deadline=None)
@given(float_arrays())
def test_quantize_roundtrip_bounded(x):
    scale, zp = quant.affine_qparams(jnp.min(x), jnp.max(x))
    q = quant.quantize(jnp.asarray(x), scale, zp)
    deq = (q.astype(jnp.float32) - zp) * scale
    err = np.max(np.abs(np.asarray(deq) - x))
    assert err <= float(scale) * 0.501 + 1e-6


@settings(max_examples=50, deadline=None)
@given(float_arrays())
def test_zero_exactly_representable(x):
    scale, zp = quant.affine_qparams(jnp.min(x), jnp.max(x))
    q0 = quant.quantize(jnp.zeros(()), scale, zp)
    deq0 = (q0.astype(jnp.float32) - zp) * scale
    assert float(deq0) == 0.0


@settings(max_examples=30, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.floats(1e-6, 0.99),
)
def test_fp32_requant_matches_gemmlowp(seed, multiplier):
    rng = np.random.default_rng(seed)
    acc = rng.integers(-(2**20), 2**20, size=(256, 8), dtype=np.int64).astype(np.int32)
    out_zp = int(rng.integers(-20, 20))

    got = np.asarray(quant.requantize(jnp.asarray(acc), jnp.float32(multiplier),
                                      jnp.int32(out_zp)))
    want = quant.requantize_gemmlowp_np(acc, multiplier, out_zp)

    diff = np.abs(got.astype(np.int32) - want.astype(np.int32))
    # agreement: identical except possibly off-by-one on round-to-even ties
    assert diff.max() <= 1
    mismatch_rate = (diff > 0).mean()
    assert mismatch_rate < 1e-2, mismatch_rate


def test_quantize_multiplier_reconstruction():
    for real in [0.25, 0.5, 0.75, 1e-4, 0.9999, 0.0001234]:
        qm, shift = quant.quantize_multiplier_np(real)
        approx = qm * 2.0 ** (shift - 31)
        assert abs(approx - real) / real < 1e-8


def test_weight_quant_per_channel_symmetric():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    qt = quant.quantize_weight(w, axis=-1)
    assert qt.q.dtype == jnp.int8
    assert qt.scale.shape == (16,)
    assert int(qt.zero_point) == 0
    assert int(jnp.max(jnp.abs(qt.q.astype(jnp.int32)))) <= 127
    # per-channel reconstruction error bounded by scale/2
    deq = qt.dequantize()
    err = jnp.max(jnp.abs(deq - w), axis=0)
    assert np.all(np.asarray(err) <= np.asarray(qt.scale) * 0.5 + 1e-7)


def test_fake_quant_idempotent_and_ste():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * 3)
    scale, zp = quant.affine_qparams(jnp.min(x), jnp.max(x))
    y = quant.fake_quant(x, scale, zp)
    y2 = quant.fake_quant(y, scale, zp)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)

    # STE: grad == 1 in-range, 0 when saturated
    big = jnp.asarray([1e6, -1e6, 0.0])
    g = jax.grad(lambda v: jnp.sum(quant.fake_quant(v, scale, zp)))(big)
    assert float(g[0]) == 0.0 and float(g[1]) == 0.0 and float(g[2]) == 1.0


def test_observer_tracks_range():
    obs = quant.MinMaxObserver(jnp.zeros(()), jnp.zeros(()), momentum=0.9)
    for i in range(100):
        obs = obs.update(jnp.asarray([-2.0, 3.0]))
    scale, zp = obs.qparams()
    assert float(scale) > 0
    # after many updates EMA approaches the true range
    assert float(obs.max_val) > 2.5 and float(obs.min_val) < -1.5
