"""Per-architecture smoke tests: every assigned arch instantiates (reduced,
same family) and runs one forward + one train step + one decode step on CPU,
asserting output shapes and finiteness.  The FULL configs are exercised via
launch/dryrun.py (ShapeDtypeStruct only)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import api as model_api
from repro.models.config import ShapeConfig, reduced
from repro.train import optim, steps

jax.config.update("jax_platform_name", "cpu")

ARCHS = registry.names()


def _cfg(name):
    c = reduced(registry.get(name))
    # keep CPU time bounded
    return dataclasses.replace(c, n_layers=min(c.n_layers, 2))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = _cfg(arch)
    params = model_api.init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    if cfg.input_mode == "embeddings":
        embeds = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
        out = model_api.forward(cfg, params, None, embeds=embeds)
    else:
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        out = model_api.forward(cfg, params, toks)
    logits = out.logits if hasattr(out, "logits") else out
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = _cfg(arch)
    opt = optim.make_optimizer(cfg.optimizer)
    state = steps.init_train_state(cfg, jax.random.key(0), opt)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(jax.random.key(2),
                                            (B, S, cfg.d_model))
    step = steps.make_train_step(cfg, None, opt)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually moved (bf16 leaves are numpy kind 'V' — test via jnp)
    moved = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(state2.params))
        if jnp.issubdtype(a.dtype, jnp.floating))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_smoke(arch):
    cfg = _cfg(arch)
    params = model_api.init_params(cfg, jax.random.key(0))
    B, max_len = 2, 32
    cache = model_api.init_cache(cfg, B, max_len)
    tok = jnp.asarray([1, 2], jnp.int32)
    if cfg.input_mode == "embeddings":
        emb = jax.random.normal(jax.random.key(3), (B, cfg.d_model))
        logits, cache = model_api.decode_step(cfg, params, None, cache,
                                              embed=emb)
    else:
        logits, cache = model_api.decode_step(cfg, params, tok, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_all_archs_have_configs():
    """The 10 assigned architectures are all registered with exact dims."""
    expect = {
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "rwkv6-1.6b": (24, 2048, None, None, 7168, 65536),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for name, (L, d, H, KV, ff, V) in expect.items():
        c = registry.get(name)
        assert c.n_layers == L and c.d_model == d and c.d_ff == ff \
            and c.vocab_size == V, name
        if H is not None:
            assert c.n_heads == H and c.n_kv_heads == KV, name
