"""Fault-tolerant training: inject → detect → restore → bit-identical replay."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import fault_injection as fi
from repro.models.config import ShapeConfig, reduced
from repro.runtime import ft_loop
from repro.runtime.orchestrator import Orchestrator

jax.config.update("jax_platform_name", "cpu")

SHAPE = ShapeConfig("tiny", seq_len=16, global_batch=4, kind="train")


def tiny_cfg():
    c = reduced(registry.get("smollm-135m"))
    import dataclasses
    return dataclasses.replace(c, n_layers=1, d_model=32, d_ff=64,
                               vocab_size=64, compute_dtype="float32",
                               param_dtype="float32")


def run_clean(tmp_path, n_steps=12):
    ft = ft_loop.FTConfig(ckpt_dir=str(tmp_path / "clean"), ckpt_every=4)
    return ft_loop.run(tiny_cfg(), SHAPE, ft, n_steps=n_steps)


def test_clean_run_trains(tmp_path):
    rep = run_clean(tmp_path)
    assert len(rep.losses) == 12
    assert rep.recoveries == 0
    assert all(np.isfinite(l) for l in rep.losses)
    # it actually learns *something* on the zipf stream
    assert np.mean(rep.losses[-4:]) < np.mean(rep.losses[:4])


def test_nan_injection_recovers_bit_identical(tmp_path):
    clean = run_clean(tmp_path)

    fired = {"done": False}

    def hook(step, state):
        if step == 9 and not fired["done"]:
            fired["done"] = True
            # SEU: NaN a weight → loss goes non-finite → detect+restore
            bad = jax.tree_util.tree_map(lambda x: x, state)
            leaf = bad.params["embed"]
            bad = bad._replace(params=dict(bad.params, embed=leaf.at[0, 0].set(jnp.nan)))
            return bad
        return None

    ft = ft_loop.FTConfig(ckpt_dir=str(tmp_path / "faulty"), ckpt_every=4)
    rep = ft_loop.run(tiny_cfg(), SHAPE, ft, n_steps=12, fault_hook=hook)
    assert rep.recoveries == 1
    assert rep.steps_replayed > 0
    # recovery must reproduce the clean loss curve EXACTLY (determinism)
    np.testing.assert_array_equal(np.asarray(rep.losses),
                                  np.asarray(clean.losses))


def test_bitflip_injection_detected_or_survived(tmp_path):
    """Random bit flips either spike the loss (→ recovery) or are benign;
    either way training completes with finite losses."""
    def hook(step, state):
        if step == 6:
            params = fi.inject_into_pytree(state.params,
                                           jax.random.key(9), n_flips=3)
            return state._replace(params=params)
        return None

    ft = ft_loop.FTConfig(ckpt_dir=str(tmp_path / "flip"), ckpt_every=3)
    rep = ft_loop.run(tiny_cfg(), SHAPE, ft, n_steps=10, fault_hook=hook)
    assert len(rep.losses) == 10
    assert all(np.isfinite(l) for l in rep.losses)


def test_resume_from_existing_checkpoint(tmp_path):
    """Kill after 8 steps, relaunch, final state == uninterrupted run."""
    d = tmp_path / "resume"
    ft = ft_loop.FTConfig(ckpt_dir=str(d), ckpt_every=4)
    rep1 = ft_loop.run(tiny_cfg(), SHAPE, ft, n_steps=8)   # "crash" at 8
    rep2 = ft_loop.run(tiny_cfg(), SHAPE, ft, n_steps=12)  # relaunch
    clean = run_clean(tmp_path)
    np.testing.assert_array_equal(np.asarray(rep2.losses),
                                  np.asarray(clean.losses[8:]))


# ----------------------------------------------------------- orchestrator

def test_orchestrator_death_and_elastic_plan():
    orch = Orchestrator(n_workers=8, heartbeat_timeout=5.0)
    for uid in range(8):
        orch.heartbeat(uid, step=10, step_time=1.0, now=100.0)
    # workers 6,7 stop reporting
    for uid in range(6):
        orch.heartbeat(uid, step=11, step_time=1.0, now=108.0)
    dead = orch.check_health(now=109.0)
    assert set(dead) == {6, 7}
    plan = orch.elastic_plan(checkpointed_step=40, model_axis=2)
    assert plan.new_world_size <= 6
    assert plan.new_mesh_shape[1] == 2
    assert plan.restore_step == 40


def test_orchestrator_straggler_detection():
    orch = Orchestrator(n_workers=4, straggler_factor=3.0, min_history=4)
    for t in range(4):
        for uid in range(4):
            dt = 1.0 if uid != 2 else (1.0 if t < 3 else 20.0)
            orch.heartbeat(uid, step=t, step_time=dt, now=float(t))
    assert orch.detect_stragglers() == [2]
    assert orch.progress()["alive"] == 4


# ------------------------------------------------- incremental checkpointing


def test_incremental_checkpointer_restart_bit_identity(tmp_path):
    """The train loop now checkpoints through IncrementalCheckpointer
    (async writer, dirty-chunk diffs, format-2 manifest chains): a kill at
    step 8 + relaunch must continue on bit-identical losses, and the dir
    must actually hold incremental manifests."""
    import json
    d = tmp_path / "inc"
    ft = ft_loop.FTConfig(ckpt_dir=str(d), ckpt_every=4, ckpt_full_every=2)
    rep1 = ft_loop.run(tiny_cfg(), SHAPE, ft, n_steps=8)   # "crash" at 8
    assert rep1.ckpt_stats["saves"] >= 2
    assert rep1.ckpt_stats["chunks_written"] > 0
    manifests = sorted(d.glob("step_*/manifest.json"))
    assert manifests
    assert all(json.loads(m.read_text())["format"] == 2 for m in manifests)

    rep2 = ft_loop.run(tiny_cfg(), SHAPE, ft, n_steps=12)  # relaunch
    clean = run_clean(tmp_path)
    np.testing.assert_array_equal(np.asarray(rep2.losses),
                                  np.asarray(clean.losses[8:]))


def test_incremental_recovery_waits_for_async_writer(tmp_path):
    """Mid-run detection must restore from a durable incremental manifest
    (ick.wait barrier) and replay the clean loss curve exactly."""
    clean = run_clean(tmp_path)

    fired = {"done": False}

    def hook(step, state):
        if step == 9 and not fired["done"]:
            fired["done"] = True
            bad = jax.tree_util.tree_map(lambda x: x, state)
            leaf = bad.params["embed"]
            return bad._replace(params=dict(
                bad.params, embed=leaf.at[0, 0].set(jnp.nan)))
        return None

    ft = ft_loop.FTConfig(ckpt_dir=str(tmp_path / "inc-faulty"),
                          ckpt_every=4, ckpt_full_every=2)
    rep = ft_loop.run(tiny_cfg(), SHAPE, ft, n_steps=12, fault_hook=hook)
    assert rep.recoveries == 1
    np.testing.assert_array_equal(np.asarray(rep.losses),
                                  np.asarray(clean.losses))
