"""Adaptive campaign engine: sequential sampling, sharding, resume, MBU.

The paper-scale claims this file certifies:
  * the sequential sampler reaches the same dependability verdicts as a
    fixed-budget campaign with measurably fewer trials (DAVOS-style
    iterative statistical injection);
  * sharded execution is bit-identical to serial — same counts, same CI
    columns, same event-derived timeline columns — because workers run key
    *slices* of the same deterministic stream and the stopping rule is
    evaluated in key order;
  * a killed campaign resumes from its crash-consistent journal and ends
    with results bit-identical to an uninterrupted run;
  * the mbu_burst fault model injects seeded clusters of adjacent cells,
    and TMR's majority vote still yields zero SDC against them.
"""
from __future__ import annotations

import json

import jax
import numpy as np
import pytest

from repro.campaign import (
    CampaignInterrupted, CampaignJournal, CampaignPool, CampaignSpec,
    ChunkOutcome, ConfigResult, SamplingPlan, binomial_interval,
    clopper_pearson_interval, halfwidth, resolve_fault_model, run_campaign,
    wilson_interval, write_report, load_report)
from repro.campaign import engine as engine_mod
from repro.campaign import runner
from repro.campaign import stats as stats_mod
from repro.core import fault_injection as fi
from repro.core.dependability import Policy

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# (a) interval math — dependency-free binomial CIs
# ---------------------------------------------------------------------------


def test_wilson_interval_basics():
    lo, hi = wilson_interval(0, 25, 0.95)
    assert lo == 0.0 and 0.0 < hi < 0.25      # never zero-width at p̂ = 0
    lo1, hi1 = wilson_interval(25, 25, 0.95)
    assert hi1 == 1.0 and 0.75 < lo1 < 1.0    # symmetric at p̂ = 1
    # symmetric complements: CI(k, n) mirrors CI(n-k, n)
    lo2, hi2 = wilson_interval(5, 50, 0.95)
    lo3, hi3 = wilson_interval(45, 50, 0.95)
    assert lo2 == pytest.approx(1.0 - hi3) and hi2 == pytest.approx(1.0 - lo3)
    # more trials ⇒ tighter interval
    assert halfwidth(wilson_interval(0, 400)) < halfwidth(wilson_interval(0, 25))


def test_clopper_pearson_matches_closed_form_at_boundary():
    # k = 0: the exact upper bound has the closed form 1 - (α/2)^(1/n)
    for n in (10, 25, 100):
        lo, hi = clopper_pearson_interval(0, n, 0.95)
        assert lo == 0.0
        assert hi == pytest.approx(1.0 - 0.025 ** (1.0 / n), abs=1e-9)
    # k = n mirrors it
    lo, hi = clopper_pearson_interval(25, 25, 0.95)
    assert hi == 1.0
    assert lo == pytest.approx(0.025 ** (1.0 / 25), abs=1e-9)


def test_clopper_pearson_is_wider_than_wilson():
    """CP is the conservative (exact) interval: never tighter than Wilson,
    so a CP-stopped campaign never stops earlier than a Wilson-stopped one
    at the same target half-width."""
    for k, n in ((0, 25), (1, 25), (3, 50), (10, 100), (50, 100), (99, 100)):
        w = wilson_interval(k, n, 0.95)
        cp = clopper_pearson_interval(k, n, 0.95)
        assert halfwidth(cp) >= halfwidth(w) - 1e-12


def test_interval_validation():
    with pytest.raises(ValueError, match="unknown CI method"):
        binomial_interval(1, 10, method="wald")
    with pytest.raises(ValueError, match="unsupported confidence"):
        wilson_interval(1, 10, confidence=0.5)
    assert binomial_interval(0, 0) == (0.0, 1.0)


def test_sampling_plan_stopping_rule():
    fixed = SamplingPlan()
    assert not fixed.adaptive
    assert not fixed.should_stop(0, 99, 100)      # fixed mode: only the cap
    assert fixed.should_stop(0, 100, 100)
    adaptive = SamplingPlan(ci_halfwidth=0.1, min_trials=25)
    assert adaptive.adaptive
    assert not adaptive.should_stop(0, 10, 1000)  # below the min-trials floor
    assert adaptive.should_stop(0, 100, 1000)     # hw(0/100) ≈ 0.026 ≤ 0.1
    assert not adaptive.should_stop(5, 25, 1000)  # hw(5/25) ≈ 0.15 > 0.1
    with pytest.raises(ValueError):
        SamplingPlan(ci_halfwidth=-1)
    with pytest.raises(ValueError):
        SamplingPlan(ci_method="wald")


# ---------------------------------------------------------------------------
# (b) adaptive early stopping reaches fixed-budget verdicts, cheaper
# ---------------------------------------------------------------------------


def test_adaptive_matches_fixed_verdicts_with_fewer_trials():
    """The acceptance claim: the adaptive run reproduces the paper verdict
    (ABFT accumulator detection = 1.0, SDC = 0) that a fixed 100-trial
    campaign certifies, in a fraction of the trials."""
    spec100 = CampaignSpec("qmatmul", Policy.ABFT, "accumulator",
                           "single_bitflip", trials=100)
    fixed = run_campaign([spec100])[0]
    assert fixed.trials == 100 and not fixed.early_stopped
    assert fixed.detection_rate == 1.0 and fixed.sdc == 0

    plan = SamplingPlan(ci_halfwidth=0.1, chunk=25, kernel_chunk=25,
                        min_trials=25)
    spec = CampaignSpec("qmatmul", Policy.ABFT, "accumulator",
                        "single_bitflip", trials=100)
    adaptive = run_campaign([spec], plan=plan)[0]
    assert adaptive.early_stopped
    assert adaptive.trials == 25                 # stops at the first boundary
    assert adaptive.trials < fixed.trials
    assert adaptive.detection_rate == 1.0 and adaptive.sdc == 0
    assert adaptive.max_trials == 100
    assert halfwidth((adaptive.sdc_ci_lo, adaptive.sdc_ci_hi)) <= 0.1
    assert adaptive.ci_method == "wilson" and adaptive.ci_confidence == 0.95


def test_adaptive_executes_exact_prefix_of_key_stream():
    """Early-stopped trials are the first N keys of the same stream the
    full-budget run uses — not a differently-seeded shorter campaign."""
    spec = CampaignSpec("qmatmul", Policy.NONE, "accumulator",
                        "single_bitflip", trials=80)
    case = runner.build_case("qmatmul")
    full = engine_mod.run_config_chunk(case, spec, 0, 80)
    plan = SamplingPlan(ci_halfwidth=0.5, chunk=20, kernel_chunk=20,
                        min_trials=20)
    acc = engine_mod.run_config(spec, plan, 20, case=case)
    assert acc.early_stopped and acc.n < 80
    assert acc.detected == full.detected[:acc.n]
    assert acc.mismatch == full.mismatch[:acc.n]


def test_nonzero_rate_needs_more_trials_than_zero_rate():
    """Sequential sampling spends trials where the estimate is noisy: a
    policy with SDC ≈ 0 certifies earlier than an unprotected one at the
    same target precision."""
    plan = SamplingPlan(ci_halfwidth=0.12, chunk=25, kernel_chunk=25,
                        min_trials=25)
    mk = lambda pol: CampaignSpec("qmatmul", pol, "accumulator",
                                  "single_bitflip", trials=400)
    abft, none = run_campaign([mk(Policy.ABFT), mk(Policy.NONE)], plan=plan)
    assert abft.sdc == 0 and abft.trials == 25
    assert none.sdc_rate > 0.2                  # unprotected: wide interval
    assert none.trials > abft.trials


# ---------------------------------------------------------------------------
# (c) mbu_burst fault model
# ---------------------------------------------------------------------------


def test_flip_burst_flips_adjacent_cluster():
    x = jax.random.randint(jax.random.key(1), (16, 16), -1000, 1000,
                           dtype=jax.numpy.int32)
    key = jax.random.key(7)
    y = fi.flip_burst(x, key, elems=2, bits=2)
    assert y.shape == x.shape and y.dtype == x.dtype
    xf, yf = np.asarray(x).ravel(), np.asarray(y).ravel()
    changed = np.nonzero(xf != yf)[0]
    assert len(changed) == 2
    assert changed[1] - changed[0] == 1          # adjacent elements
    diffs = xf[changed] ^ yf[changed]
    assert (diffs == diffs[0]).all()             # same mask on both cells
    bits_set = np.nonzero([(int(diffs[0]) >> b) & 1 for b in range(32)])[0]
    assert len(bits_set) == 2 and bits_set[1] - bits_set[0] == 1
    # deterministic in the key
    y2 = fi.flip_burst(x, key, elems=2, bits=2)
    assert (np.asarray(y) == np.asarray(y2)).all()


def test_flip_burst_clamps_to_tensor_and_word():
    x = jax.numpy.asarray([[3]], dtype=jax.numpy.int32)
    y = fi.flip_burst(x, jax.random.key(0), elems=4, bits=64)
    assert y.shape == x.shape
    assert int(y[0, 0]) != 3                     # burst still landed
    # vmap over keys compiles (static cluster geometry)
    keys = jax.random.split(jax.random.key(0), 5)
    big = jax.random.normal(jax.random.key(2), (8, 8), jax.numpy.float32)
    out = jax.vmap(lambda k: fi.flip_burst(big, k, 3, 2))(keys)
    assert out.shape == (5, 8, 8)


def test_mbu_burst_model_resolution():
    assert resolve_fault_model("mbu_burst").name == "mbu_burst"
    assert resolve_fault_model("mbu_burst@3x2").name == "mbu_burst@3x2"
    # default geometry spelled explicitly normalizes to the default name
    assert resolve_fault_model("mbu_burst@2x2").name == "mbu_burst"
    with pytest.raises(KeyError, match="mbu_burst@<elems>x<bits>"):
        resolve_fault_model("mbu_burst@banana")
    with pytest.raises(KeyError):
        resolve_fault_model("mbu_burst@0x2")


def test_mbu_burst_campaign_tmr_zero_sdc():
    """Majority vote is burst-agnostic: a whole cluster corrupts only one
    replica, so TMR still yields zero SDC — while the unprotected kernel
    shows the burst is genuinely more damaging than a single flip."""
    mk = lambda pol, fm: CampaignSpec("qmatmul", pol, "accumulator", fm,
                                      trials=40)
    tmr, none_burst = run_campaign([
        mk(Policy.TMR, "mbu_burst"),
        mk(Policy.NONE, "mbu_burst")])
    assert tmr.sdc == 0
    assert none_burst.sdc > 0
    # deterministic replay
    again = run_campaign([mk(Policy.NONE, "mbu_burst")])[0]
    assert again == none_burst


def test_mbu_burst_on_serving_kv_cache():
    spec = CampaignSpec("serving", Policy.ABFT, "kv_cache", "mbu_burst",
                        trials=6)
    r = run_campaign([spec])[0]
    assert r.trials == 6
    assert r.sdc == 0                   # kv guard catches the whole cluster
    assert r.detection_rate == 1.0


# ---------------------------------------------------------------------------
# (d) resume from the crash-consistent journal
# ---------------------------------------------------------------------------


def _qm_spec(trials=48):
    return CampaignSpec("qmatmul", Policy.NONE, "accumulator",
                        "single_bitflip", trials=trials)


def test_resume_after_midconfig_kill_is_bit_identical(tmp_path):
    plan = SamplingPlan(chunk=16, kernel_chunk=16)
    uninterrupted = run_campaign([_qm_spec()], plan=plan)[0]

    journal = CampaignJournal(tmp_path / "journal")
    with pytest.raises(CampaignInterrupted):
        run_campaign([_qm_spec()], plan=plan, journal=journal,
                     _abort_after_chunks=1)
    rec = journal.load(_qm_spec())
    assert rec is not None and not rec["done"]
    assert rec["trials_done"] == 16

    stats: dict = {}
    resumed = run_campaign([_qm_spec()], plan=plan, journal=journal,
                           run_stats=stats)[0]
    assert resumed == uninterrupted
    assert stats["trials_resumed"] == 16 and stats["trials_live"] == 32
    # a third run touches nothing: the record is done
    stats2: dict = {}
    final = run_campaign([_qm_spec()], plan=plan, journal=journal,
                         run_stats=stats2)[0]
    assert final == uninterrupted
    assert stats2["trials_live"] == 0 and stats2["configs_resumed"] == 1


def test_journal_discards_mismatched_spec(tmp_path):
    """jax.random.split is not prefix-stable across counts: a record written
    under a different trial cap must be discarded, never continued."""
    journal = CampaignJournal(tmp_path)
    plan = SamplingPlan(chunk=16, kernel_chunk=16)
    run_campaign([_qm_spec(48)], plan=plan, journal=journal)
    assert journal.load(_qm_spec(48)) is not None
    assert journal.load(_qm_spec(64)) is None
    stats: dict = {}
    run_campaign([_qm_spec(64)], plan=plan, journal=journal, run_stats=stats)
    assert stats["trials_resumed"] == 0 and stats["trials_live"] == 64


def test_journal_tolerates_corruption(tmp_path):
    journal = CampaignJournal(tmp_path)
    spec = _qm_spec()
    path = journal.path_for(spec)
    path.write_text("{ torn json")
    assert journal.load(spec) is None
    assert journal.records() == {}
    # a stale .tmp from a crash mid-publish is simply ignored
    path.with_suffix(".tmp").write_text("garbage")
    journal.publish(spec, [], done=False)
    assert journal.load(spec)["trials_done"] == 0


def test_chunk_outcome_roundtrips_events():
    from repro.obs.events import Event
    oc = ChunkOutcome(lo=5, hi=7, detected=[True, False],
                      mismatch=[False, True], recovery_count=1,
                      recovery_seconds=[0.25],
                      events=[Event(tick=3, kind="strike", site="kv_cache",
                                    policy="abft", fault="mbu_burst",
                                    detail={"x": 1})])
    back = ChunkOutcome.from_doc(json.loads(json.dumps(oc.to_doc())))
    assert back == oc


# ---------------------------------------------------------------------------
# (e) sharded execution — bit-identical to serial (subprocess pool)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pool():
    with CampaignPool(2) as p:
        yield p


@pytest.mark.slow
def test_sharded_bit_identical_to_serial(pool):
    spec = CampaignSpec("shipdet", Policy.TMR, "weights",
                        "single_bitflip", trials=12)
    serial = run_campaign([spec], plan=SamplingPlan(chunk=4))[0]
    sharded = run_campaign([spec], plan=SamplingPlan(chunk=4, workers=2),
                           pool=pool)[0]
    assert sharded == serial


@pytest.mark.slow
def test_sharded_adaptive_stops_at_serial_boundary(pool):
    """Speculative chunks computed past the stopping boundary are discarded:
    the sharded adaptive run executes exactly the serial trial set."""
    spec = CampaignSpec("shipdet", Policy.TMR, "weights",
                        "single_bitflip", trials=12)
    plan = SamplingPlan(ci_halfwidth=0.2, chunk=4, min_trials=4)
    serial = run_campaign([spec], plan=plan)[0]
    sharded = run_campaign([spec],
                           plan=SamplingPlan(ci_halfwidth=0.2, chunk=4,
                                             min_trials=4, workers=2),
                           pool=pool)[0]
    assert serial.early_stopped and serial.trials < 12
    assert sharded == serial


@pytest.mark.slow
def test_sharded_resume_bit_identical(pool, tmp_path):
    spec = CampaignSpec("shipdet", Policy.TMR, "weights",
                        "single_bitflip", trials=12)
    plan = SamplingPlan(chunk=4, workers=2)
    uninterrupted = run_campaign([spec], plan=plan, pool=pool)[0]
    journal = CampaignJournal(tmp_path / "journal")
    with pytest.raises(CampaignInterrupted):
        run_campaign([spec], plan=plan, pool=pool, journal=journal,
                     _abort_after_chunks=1)
    stats: dict = {}
    resumed = run_campaign([spec], plan=plan, pool=pool, journal=journal,
                           run_stats=stats)[0]
    assert resumed == uninterrupted
    assert stats["trials_resumed"] == 4


# ---------------------------------------------------------------------------
# (f) adaptive bit sweep + report/CLI round trips
# ---------------------------------------------------------------------------


def test_adaptive_bit_sweep_stops_early_per_policy():
    from repro.campaign.runner import ACC_BITS, run_bit_sweep
    plan = SamplingPlan(ci_halfwidth=0.5, chunk=4, min_trials=4)
    rows = run_bit_sweep("qmatmul", [Policy.NONE], trials_per_bit=16,
                         plan=plan)
    assert len(rows) == ACC_BITS
    assert all(r.trials == rows[0].trials for r in rows)
    assert rows[0].trials < 16                   # stopped before the cap
    fixed = run_bit_sweep("qmatmul", [Policy.NONE], trials_per_bit=16)
    assert all(r.trials == 16 for r in fixed)
    # the adaptive sweep's verdict structure matches the fixed one
    assert {r.bit: r.sdc > 0 for r in rows}[31] \
        == {r.bit: r.sdc > 0 for r in fixed}[31]


def test_config_result_ci_columns_roundtrip(tmp_path):
    plan = SamplingPlan(ci_halfwidth=0.1, chunk=25, kernel_chunk=25,
                        min_trials=25, ci_method="clopper-pearson")
    res = run_campaign([CampaignSpec("qmatmul", Policy.ABFT, "accumulator",
                                     "single_bitflip", trials=100)],
                       plan=plan)
    write_report(res, tmp_path, {"note": "ci"})
    _, loaded = load_report(tmp_path / "campaign.json")
    assert loaded[0] == res[0]
    assert loaded[0].ci_method == "clopper-pearson"
    assert loaded[0].early_stopped and loaded[0].max_trials == 100
    # legacy reports (no CI columns) still load, with inert defaults
    legacy = ConfigResult.from_dict({
        "workload": "qmatmul", "policy": "abft", "site": "accumulator",
        "fault_model": "single_bitflip", "trials": 10, "masked": 0,
        "detected_corrected": 10, "detected_uncorrected": 0, "sdc": 0})
    assert legacy.max_trials == 0 and legacy.ci_method == ""


def test_cli_adaptive_run_and_resume(tmp_path):
    from repro.campaign import cli
    out = tmp_path / "camp"
    argv = ["--workload", "qmatmul", "--policies", "none,abft",
            "--sites", "accumulator", "--fault-models", "single_bitflip",
            "--trials", "60", "--ci-halfwidth", "0.12", "--chunk", "20",
            "--kernel-chunk", "20", "--min-trials", "20",
            "--bit-trials", "0", "--quiet", "--out", str(out)]
    assert cli.main(argv) == 0
    meta, rows = load_report(out / "campaign.json")
    assert meta["ci_halfwidth"] == 0.12 and meta["ci_method"] == "wilson"
    abft = [r for r in rows if r.policy == "abft"][0]
    assert abft.early_stopped and abft.trials < 60
    assert (out / "journal").is_dir()

    # resume: everything is already journaled — zero live trials, same rows
    argv2 = ["--workload", "qmatmul", "--policies", "none,abft",
             "--sites", "accumulator", "--fault-models", "single_bitflip",
             "--trials", "60", "--ci-halfwidth", "0.12", "--chunk", "20",
             "--kernel-chunk", "20", "--min-trials", "20",
             "--bit-trials", "0", "--quiet", "--resume", str(out)]
    assert cli.main(argv2) == 0
    meta2, rows2 = load_report(out / "campaign.json")
    assert meta2["trials_live"] == 0
    assert meta2["configs_resumed"] == len(rows2) == len(rows)
    assert rows2 == rows
