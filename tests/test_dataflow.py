"""Streaming dataflow executor: queue/stage primitives, continuous-batching
bit-identity with mid-decode joins across model families, per-stage fault
injection, the certify release gate, and the pad-and-step drain barrier."""
from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import fault_injection as fi
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime import dataflow as df
from repro.runtime.serving import Engine, Request

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Channel / stage primitives
# ---------------------------------------------------------------------------


def test_channel_fifo_and_capacity():
    ch = df.Channel(2, "t")
    assert ch.try_put(1) and ch.try_put(2)
    assert ch.full() and not ch.try_put(3)
    assert ch.try_get() == 1
    assert ch.try_put(3)
    assert [ch.try_get(), ch.try_get()] == [2, 3]
    assert df.Channel.is_empty_token(ch.try_get())


def test_channel_unbounded_and_drain():
    ch = df.Channel(0)
    for i in range(100):
        assert ch.try_put(i)
    assert not ch.full()
    assert len(ch) == 100
    assert ch.drain() == list(range(100))
    assert len(ch) == 0


def test_channel_blocking_put_unblocks_on_get():
    ch = df.Channel(1)
    ch.put("a")
    got = []

    def producer():
        ch.put("b")               # blocks until the consumer makes room
        got.append("sent")

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not got                # still blocked at capacity
    assert ch.get() == "a"
    t.join(timeout=2.0)
    assert got == ["sent"] and ch.get() == "b"


def test_channel_close_raises_closed():
    ch = df.Channel(1)
    ch.close()
    with pytest.raises(df.Closed):
        ch.put(1)
    with pytest.raises(df.Closed):
        ch.get()


def test_source_stage_cooperative_pump_is_ordered():
    out = df.Channel(3)
    stage = df.SourceStage(lambda i: i * 10, out, start=4)
    assert stage.pump()           # fills to capacity, then parks the next
    assert list(out) == [40, 50, 60]
    assert out.try_get() == 40
    stage.pump()
    assert list(out) == [50, 60, 70]


def test_threaded_source_streams_deterministically():
    out = df.Channel(2)
    driver = df.ThreadedSource(df.SourceStage(lambda i: i, out)).start()
    assert [out.get() for _ in range(20)] == list(range(20))
    driver.close()


# ---------------------------------------------------------------------------
# Continuous batching: bit-identity with requests joining mid-decode
# ---------------------------------------------------------------------------


def greedy_reference(cfg, params, prompt, n_new, max_len=96):
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = model_api.prefill(cfg, params, toks, max_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = model_api.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


FAMILY_ARCHS = ["smollm-135m", "rwkv6-1.6b", "recurrentgemma-2b"]


@pytest.fixture(scope="module", params=FAMILY_ARCHS)
def family(request):
    cfg = reduced(registry.get(request.param))
    params = model_api.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_mid_decode_joins_are_bit_identical(family):
    """Requests that join the slotted batch while neighbors are mid-decode
    must produce exactly the tokens a solo greedy decode produces — the
    continuous-batching invariant, across transformer/rwkv/hybrid."""
    cfg, params = family
    early = [[5, 9, 2], [3, 1, 4, 1]]
    late = [[2, 7, 1], [8, 8]]
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=8)
            for i, p in enumerate(early)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()                       # both early requests mid-decode
    late_reqs = [Request(uid=10 + i, prompt=list(p), max_new_tokens=8)
                 for i, p in enumerate(late)]
    for r in late_reqs:
        eng.submit(r)                    # join as early slots free up
    eng.run()
    for r, p in zip(reqs + late_reqs, early + late):
        assert r.output == greedy_reference(cfg, params, p, 8), f"uid {r.uid}"


def test_drain_barrier_changes_schedule_not_tokens(family):
    """The pad-and-step baseline mode (drain_barrier) must decode more steps
    on a mixed-length trace (idle slots) yet emit the identical streams —
    scheduling policy can never change tokens."""
    cfg, params = family
    prompts = [[5, 9, 2], [3, 1, 4, 1], [2, 7], [8, 8, 6]]
    budgets = [2, 8, 2, 8]

    def serve(drain):
        eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                     drain_barrier=drain)
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, budgets))]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [list(r.output) for r in reqs], eng.stats.steps

    streamed, s_steps = serve(False)
    padded, p_steps = serve(True)
    assert streamed == padded
    assert p_steps > s_steps             # the barrier wastes slot-steps


# ---------------------------------------------------------------------------
# Pipeline structure, per-stage injection, certify gate
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced(registry.get("smollm-135m"))
    params = model_api.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_stage_topology_and_in_flight_order(smollm):
    cfg, params = smollm
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    ex = eng.executor
    assert [s.name for s in ex.stages] == [
        "admit", "prefill", "decode", "certify", "release"]
    reqs = [Request(uid=i, prompt=[1 + i, 2], max_new_tokens=4)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    assert [r.uid for r in ex.in_flight()] == [0, 1, 2, 3]
    eng.step()
    # two in decode slots, two still queued — stage-then-slot order
    assert [r.uid for r in ex.in_flight()] == [2, 3, 0, 1]
    eng.run()


def test_strike_decode_state_is_caught_by_scrub(smollm):
    """Per-stage injection drills the decode stage's token buffer; the
    pre-decode scrub guard must catch it before the next step consumes it."""
    cfg, params = smollm
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 snapshot_every=2, state_scrub="rollback")
    eng.submit(Request(uid=0, prompt=[5, 9, 2], max_new_tokens=6))
    eng.step()
    eng.step()
    eng.strike("decode_state", fi.flip_one_bit, jax.random.key(3))
    eng.run()
    events = eng.drain_state_events()
    assert len(events) == 1 and events[0]["recovered"]


def test_strike_kv_cache_and_weights_route_to_owners(smollm):
    cfg, params = smollm
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    before_cache = jax.tree_util.tree_leaves(eng.cache)
    before_params = jax.tree_util.tree_leaves(eng.params)
    eng.submit(Request(uid=0, prompt=[5, 9, 2], max_new_tokens=4))
    eng.step()
    eng.strike("kv_cache", fi.flip_one_bit, jax.random.key(1))
    eng.strike("weights", fi.flip_one_bit, jax.random.key(2))
    after_cache = jax.tree_util.tree_leaves(eng.cache)
    after_params = jax.tree_util.tree_leaves(eng.params)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(before_cache, after_cache))
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(before_params, after_params))
    with pytest.raises(ValueError, match="no stage owns"):
        eng.strike("flux_capacitor", fi.flip_one_bit, jax.random.key(0))


def test_certify_hook_withholds_and_releases(smollm):
    """The certify stage is the release gate: a False-returning hook keeps
    finished requests out of step()'s released stream (the hook's owner has
    custody); a True-returning hook passes them through."""
    cfg, params = smollm
    held = []
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 certify=lambda req: (held.append(req), False)[1])
    reqs = [Request(uid=i, prompt=[1 + i, 5], max_new_tokens=3)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    released = []
    while eng.executor.busy():
        released += eng.step()
    assert released == []
    assert sorted(r.uid for r in held) == [0, 1]
    assert all(r.finished_at > 0 for r in held)   # finished, just not released

    eng.certify = lambda req: True
    eng.reset()
    for r in reqs:
        r.output = None
        r.finished_at = 0.0
        eng.submit(r)
    released = []
    while eng.executor.busy():
        released += eng.step()
    assert sorted(r.uid for r in released) == [0, 1]


def test_fleet_release_gate_lives_in_certify_stage(smollm):
    """A scrub-gated fleet must flow finished requests through the replica
    engines' certify stages: engines release nothing themselves, the
    replica's uncertified list takes custody until the weight scrub."""
    from repro.core.dependability import Policy
    from repro.fleet import Fleet
    cfg, params = smollm
    fleet = Fleet(cfg, params, n_replicas=2, policy=Policy.ABFT,
                  capacity=2, max_len=96, prefill_pad=8, scrub_every=1000)
    try:
        assert all(r.engine.certify is not None for r in fleet.replicas)
        req = Request(uid=0, prompt=[5, 9, 2], max_new_tokens=3)
        assert fleet.submit(req)
        for _ in range(10):
            fleet.tick()
        # finished but withheld: certification (scrub cadence) never came
        assert req.finished_at > 0
        assert req.uid not in fleet.released
        assert any(any(q.uid == req.uid for q in r.uncertified)
                   for r in fleet.replicas)
        fleet.run()                       # final certification settles it
        assert req.uid in fleet.released
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# Decode-path request-loss regressions + multi-step dispatch
# ---------------------------------------------------------------------------


def test_finished_requests_survive_full_outbox(smollm):
    """Regression: finished requests used to be handed to ``outbox.try_put``
    unchecked — a full bounded channel silently dropped them.  Rewire the
    decode→certify and certify→release hops to capacity-1 channels and
    finish two requests in the same pump: hold-and-retry must deliver both."""
    cfg, params = smollm
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8)
    ex = eng.executor
    certify_ch = df.Channel(1, "finished")
    release_ch = df.Channel(1, "certified")
    ex._certify_ch, ex._release_ch = certify_ch, release_ch
    ex.decode.outbox = certify_ch
    ex.certifier.inbox, ex.certifier.outbox = certify_ch, release_ch
    ex.release.inbox = release_ch

    prompts = [[5, 9, 2], [3, 1, 4]]
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    released = []
    while eng.executor.busy():
        released += eng.step()
    assert sorted(r.uid for r in released) == [0, 1]      # none dropped
    for r, p in zip(reqs, prompts):
        assert r.output == greedy_reference(cfg, params, p, 4), f"uid {r.uid}"


def test_prefill_eos_finishes_at_admission(smollm):
    """A request whose *first* generated token is EOS must finish at join —
    previously the EOS check only ran in the decode loop, so the request
    burned its whole token budget decoding past its own terminator."""
    cfg, params = smollm
    prompt = [5, 9, 2]
    t0 = greedy_reference(cfg, params, prompt, 1)[0]
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 eos_id=t0)
    req = Request(uid=0, prompt=list(prompt), max_new_tokens=8)
    other = Request(uid=1, prompt=[8, 8, 6], max_new_tokens=3)
    eng.submit(req)
    eng.submit(other)
    released = []
    while eng.executor.busy():
        released += eng.step()
    assert req.output == [t0]                 # terminated at admission
    assert req.finished_at > 0
    assert sorted(r.uid for r in released) == [0, 1]
    assert len(other.output) >= 1             # neighbor unaffected


@pytest.mark.parametrize("multi_step", [1, 4])
def test_decode_truncates_at_max_len(smollm, multi_step):
    """The ``slot_pos >= max_len - 1`` guard: a budget larger than the
    remaining cache rows must truncate the stream exactly at the cache edge,
    not overrun the buffer.  Regression: a budget >= max_len used to slice
    the prompt to *empty* at prefill and crash the engine (killing every
    in-flight request); now the prompt keeps at least one token and
    generation fills the remaining cache rows."""
    cfg, params = smollm
    max_len, prompt = 12, [5, 9, 2]
    eng = Engine(cfg, params, capacity=2, max_len=max_len, prefill_pad=8,
                 multi_step=multi_step)
    req = Request(uid=0, prompt=list(prompt), max_new_tokens=64)
    eng.submit(req)
    eng.run()
    # budget (64) >= max_len reserves all but one cache row for generation:
    # effective prompt is prompt[:1], stream truncates at pos == max_len - 1
    eff = prompt[:1]
    want_len = max_len - len(eff)
    assert len(req.output) == want_len
    assert req.output == greedy_reference(cfg, params, eff, want_len,
                                          max_len=max_len)
    assert req.finished_at > 0


def test_multi_step_windows_are_bit_identical(family):
    """The tentpole invariant: an N-step on-device decode window (one host
    readback per window) must emit exactly the per-step schedule's tokens —
    across the transformer / rwkv / hybrid families."""
    cfg, params = family
    prompts = [[5, 9, 2], [3, 1, 4, 1], [2, 7], [8, 8, 6]]
    budgets = [2, 8, 2, 8]

    def serve(multi_step):
        eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                     multi_step=multi_step)
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, budgets))]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [list(r.output) for r in reqs], eng.stats.steps

    per_step, s1 = serve(1)
    windowed, s4 = serve(4)
    assert windowed == per_step
    # windowed decode may burn drain-tail slot-steps, never fewer steps
    assert s4 >= s1


def test_multi_step_snapshot_rollback_still_bit_exact(smollm):
    """Snapshots land on window boundaries under multi-step dispatch; a
    mid-run state strike must roll back and still finish bit-exact."""
    cfg, params = smollm
    prompt, n_new = [5, 9, 2], 16    # budget must outlive two 4-step windows
    golden = greedy_reference(cfg, params, prompt, n_new)
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 multi_step=4, snapshot_every=2, state_scrub="rollback")
    req = Request(uid=0, prompt=list(prompt), max_new_tokens=n_new)
    eng.submit(req)
    eng.step()
    eng.step()
    eng.strike("decode_state", fi.flip_one_bit, jax.random.key(3))
    eng.run()
    events = eng.drain_state_events()
    assert len(events) == 1 and events[0]["recovered"]
    assert req.output == golden


def test_failover_bit_exact_hybrid_family():
    """Fleet failover replay on the staged executor, hybrid (griffin)
    family: killing a replica mid-decode must not change any released
    token."""
    from repro.core.dependability import Policy
    from repro.fleet import Fleet, ReplicaState
    cfg = reduced(registry.get("recurrentgemma-2b"))
    params = model_api.init_params(cfg, jax.random.key(0))
    prompts = [[5, 9, 2], [3, 1, 4, 1], [2, 7]]
    fleet = Fleet(cfg, params, n_replicas=2, policy=Policy.NONE,
                  capacity=2, max_len=96, prefill_pad=8)
    try:
        def serve(kill):
            fleet.reset()
            reqs = [Request(uid=i, prompt=list(p), max_new_tokens=5)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                assert fleet.submit(r)
            if kill:
                fleet.tick()
                fleet.tick()
                fleet.kill_replica(0)
            fleet.run()
            return [list(r.output) for r in reqs]

        golden = serve(kill=False)
        replayed = serve(kill=True)
        assert fleet.replicas[0].state is ReplicaState.DEAD
        assert fleet.metrics.failovers > 0
        assert replayed == golden
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# Channel property tests: randomized interleavings (seeded, deterministic)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_channel_random_interleaving_fifo_no_loss_no_dup(seed):
    """Property: under any seeded schedule of try_put/try_get, a channel
    never loses, duplicates, or reorders an item — accepted puts come out
    exactly once, in order, and rejections happen iff the channel was full
    (/empty) at the call."""
    import random
    rng = random.Random(seed)
    cap = rng.choice([0, 1, 2, 5])
    ch = df.Channel(cap, f"prop{seed}")
    sent, got = [], []
    nxt = 0
    for _ in range(500):
        if rng.random() < 0.5:
            was_full = ch.full()
            accepted = ch.try_put(nxt)
            assert accepted == (not was_full)
            if accepted:
                sent.append(nxt)
                nxt += 1
        else:
            was_empty = len(ch) == 0
            item = ch.try_get()
            if was_empty:
                assert df.Channel.is_empty_token(item)
            else:
                assert not df.Channel.is_empty_token(item)
                got.append(item)
    assert got + ch.drain() == sent


@pytest.mark.parametrize("seed", range(4))
def test_channel_streaming_close_propagates_exactly_once(seed):
    """Property: closing a channel under concurrent blocking put/get wakes
    both sides, each side sees ``Closed`` exactly once, and every item the
    producer successfully put is delivered (close never drops queued
    work)."""
    import random
    rng = random.Random(seed)
    ch = df.Channel(rng.choice([1, 2, 4]), f"close{seed}")
    produced, consumed = [], []
    closed_seen = {"producer": 0, "consumer": 0}

    def producer():
        i = 0
        while True:
            try:
                ch.put(i)
            except df.Closed:
                closed_seen["producer"] += 1
                return
            produced.append(i)
            i += 1

    def consumer():
        while True:
            try:
                consumed.append(ch.get())
            except df.Closed:
                closed_seen["consumer"] += 1
                return

    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start()
    tc.start()
    time.sleep(0.01 + rng.random() * 0.03)
    ch.close()
    tp.join(timeout=5)
    tc.join(timeout=5)
    assert not tp.is_alive() and not tc.is_alive()
    assert closed_seen == {"producer": 1, "consumer": 1}
    # no loss, no dup, FIFO: the consumer drained everything that was put
    assert consumed == produced


def test_channel_cooperative_spsc_threaded_no_loss():
    """The cooperative API's lock-free claim, exercised for real: one
    producer spinning try_put against a bounded channel, one consumer
    spinning try_get — every item arrives exactly once, in order."""
    ch = df.Channel(4, "spsc")
    n = 2000
    got = []

    def produce():
        i = 0
        while i < n:
            if ch.try_put(i):
                i += 1

    def consume():
        while len(got) < n:
            item = ch.try_get()
            if not df.Channel.is_empty_token(item):
                got.append(item)

    tp = threading.Thread(target=produce)
    tc = threading.Thread(target=consume)
    tp.start()
    tc.start()
    tp.join(timeout=30)
    tc.join(timeout=30)
    assert got == list(range(n))
