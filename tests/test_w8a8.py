"""W8A8 quantized FFN (cfg.quant="w8a8_ffn") — the paper's integer-arithmetic
technique as a first-class LM feature.  Property tests via hypothesis on the
weight quantizer; numeric agreement vs the float path on dense + MoE."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import transformer as tfm
from repro.models.config import ArchConfig, MoEConfig

jax.config.update("jax_platform_name", "cpu")


def base_cfg(**kw):
    d = dict(name="t", family="transformer", n_layers=2, d_model=32,
             n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128, head_dim=8,
             compute_dtype="float32")
    d.update(kw)
    return ArchConfig(**d)


MOE = MoEConfig(n_experts=4, top_k=2, d_expert=16, n_shared_experts=1,
                n_dense_layers=1, capacity_factor=8.0)


# ------------------------------ quantizer props -----------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 24), st.integers(2, 24),
       st.floats(0.01, 100.0), st.integers(0, 2**31 - 1))
def test_quantize_ffn_weight_roundtrip(k, n, scale_mag, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((k, n)) * scale_mag, jnp.float32)
    w_q, w_s = tfm.quantize_ffn_weight(w)
    assert w_q.dtype == jnp.int8 and w_s.shape == (n,)
    # dequantization error bounded by half a step per element
    deq = w_q.astype(jnp.float32) * w_s[None, :]
    err = np.asarray(jnp.abs(deq - w))
    step = np.asarray(w_s)[None, :]
    assert (err <= 0.5 * step + 1e-6).all()
    # int8 range honored, per-channel max hits ±127 (scale is tight)
    assert int(jnp.max(jnp.abs(w_q.astype(jnp.int32)))) <= 127


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(2, 16), st.integers(2, 16),
       st.integers(0, 2**31 - 1))
def test_quantize_ffn_weight_stacked(L, k, n, seed):
    """Stacked (L, K, N) weights quantize per (layer, channel)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((L, k, n)), jnp.float32)
    w_q, w_s = tfm.quantize_ffn_weight(w)
    assert w_s.shape == (L, n)
    for l in range(L):
        q1, s1 = tfm.quantize_ffn_weight(w[l])
        np.testing.assert_array_equal(np.asarray(w_q[l]), np.asarray(q1))
        np.testing.assert_allclose(np.asarray(w_s[l]), np.asarray(s1),
                                   rtol=1e-6)


# ------------------------------ model agreement -----------------------------

@pytest.mark.parametrize("moe", [None, MOE], ids=["dense", "moe"])
def test_w8a8_matches_float_forward(moe):
    cfg_f = base_cfg(moe=moe)
    cfg_q = dataclasses.replace(cfg_f, quant="w8a8_ffn")
    pf = tfm.init_params(cfg_f, jax.random.key(0))
    pq = tfm.init_params(cfg_q, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 128)
    of = tfm.forward(cfg_f, pf, toks).logits
    oq = tfm.forward(cfg_q, pq, toks).logits
    rel = float(jnp.linalg.norm(oq - of) / jnp.linalg.norm(of))
    assert rel < 0.1, rel


def test_w8a8_params_are_int8():
    cfg = base_cfg(quant="w8a8_ffn", moe=MOE)
    p = tfm.init_params(cfg, jax.random.key(0))
    mb = p["moe_blocks"]
    for name in ("we_g", "we_i", "we_o", "ws_g", "ws_i", "ws_o"):
        assert name not in mb
        assert mb[name + "_q"].dtype == jnp.int8
        assert mb[name + "_s"].dtype == jnp.float32
    db = p["dense_blocks"]
    for name in ("wg", "wi", "wd"):
        assert db[name + "_q"].dtype == jnp.int8


def test_w8a8_decode_consistent_with_prefill():
    """Batch prefill then token-by-token decode agree under quantization."""
    cfg = base_cfg(quant="w8a8_ffn")
    p = tfm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 8), 0, 128)
    full = tfm.forward(cfg, p, toks).logits          # (1, 8, V)
    logits, cache = tfm.prefill(cfg, p, toks, 16)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    dec, cache = tfm.decode_step(cfg, p, nxt, cache)
    assert np.isfinite(np.asarray(dec)).all()


def test_w8a8_sharding_specs_cover_quant_params():
    from repro.parallel import sharding as shd
    cfg = base_cfg(quant="w8a8_ffn", moe=MOE)
    p = tfm.init_params(cfg, jax.random.key(0))
    specs = shd.param_specs(cfg, p)
    flat_p = jax.tree_util.tree_leaves_with_path(p)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: hasattr(x, "_partitions") or
        type(x).__name__ == "PartitionSpec")
    assert len(flat_p) == len(flat_s)
