"""Execution-backend registry: parity, fused checksums, selection rules.

The paper's swappable-co-processor claim, as testable properties:

  * ref / jnp / pallas(interpret=True) are **bit-identical** for qmatmul and
    qconv2d under every dependability policy — the integer hot path is exact
    mod 2^32, so where the accumulator is computed cannot change it.
  * The fused pallas checksum (emitted as a second kernel output) satisfies
    the Huang–Abraham identity want == rowsum(acc) on clean runs and detects
    every injected accumulator bit-flip — certifying ABFT on the paper's
    actual kernel path, not just the jnp stand-in.
  * Selection precedence: per-call beats the ``use_backend`` scope, which
    beats the process default.
  * TMR reports the faults its majority vote masks (``faults_corrected``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import abft, backend as backend_mod
from repro.core.dependability import (
    DependabilityStats, Policy, dependable_qconv2d, dependable_qmatmul)
from repro.kernels import dispatch

jax.config.update("jax_platform_name", "cpu")

BACKENDS = ("ref", "jnp", "pallas")
POLICIES = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.TMR)


def _mm_case(rng, m=17, k=70, n=24):
    x_q = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n), dtype=np.int32), jnp.int8)
    bias = jnp.asarray(rng.integers(-500, 500, (n,), dtype=np.int32))
    scale = jnp.full((n,), 1e-3, jnp.float32)
    return x_q, w_q, bias, scale


def _conv_case(rng, h=9, w=9, cin=5, cout=6):
    x_q = jnp.asarray(rng.integers(-128, 128, (2, h, w, cin), dtype=np.int32),
                      jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (3, 3, cin, cout), dtype=np.int32),
                      jnp.int8)
    bias = jnp.asarray(rng.integers(-100, 100, (cout,), dtype=np.int32))
    scale = jnp.full((cout,), 1e-3, jnp.float32)
    return x_q, w_q, bias, scale


# ---------------------------------------------------------------------------
# Bit-identical parity across backends, every policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("policy", POLICIES)
def test_qmatmul_backend_parity(backend, policy):
    rng = np.random.default_rng(11)
    x_q, w_q, bias, scale = _mm_case(rng)
    y, _ = dependable_qmatmul(policy, x_q, jnp.int32(3), w_q, bias, scale,
                              jnp.int32(0), backend=backend)
    y_jnp, _ = dependable_qmatmul(policy, x_q, jnp.int32(3), w_q, bias, scale,
                                  jnp.int32(0), backend="jnp")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_jnp))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("stride,padding", [((1, 1), "SAME"),
                                            ((2, 2), "SAME"),
                                            ((1, 1), "VALID")])
def test_qconv2d_backend_parity(backend, policy, stride, padding):
    rng = np.random.default_rng(7)
    x_q, w_q, bias, scale = _conv_case(rng)
    y, _ = dependable_qconv2d(policy, x_q, jnp.int32(2), w_q, bias, scale,
                              jnp.int32(0), stride=stride, padding=padding,
                              backend=backend)
    y_jnp, _ = dependable_qconv2d(policy, x_q, jnp.int32(2), w_q, bias, scale,
                                  jnp.int32(0), stride=stride, padding=padding,
                                  backend="jnp")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_jnp))


@pytest.mark.parametrize("backend", BACKENDS)
def test_raw_accumulator_parity(backend):
    """The registry's accumulator-level contract itself (no policy layer)."""
    rng = np.random.default_rng(3)
    x_q, w_q, _, _ = _mm_case(rng, m=33, k=130, n=40)
    acc = dispatch.matmul_acc(x_q, w_q, backend=backend)
    want = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(want))


def test_pallas_acc_kernels_multiblock_with_tails():
    """Forced multi-block grids with ragged K/N tails: the k-tail masking and
    the cross-block (n==0 / c==0) fused-checksum accumulation paths, which
    default block sizes never reach at test geometry."""
    from repro.kernels.qconv2d.kernel import qconv2d_acc_checksum
    from repro.kernels.qmatmul.kernel import qmatmul_acc, qmatmul_acc_checksum
    rng = np.random.default_rng(31)
    x_q, w_q, _, _ = _mm_case(rng, m=33, k=130, n=70)
    want = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    acc = qmatmul_acc(x_q, w_q, block_m=16, block_n=32, block_k=48,
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(want))
    w_check = abft.checksum_vector(w_q)
    acc, got = qmatmul_acc_checksum(x_q, w_q, w_check, block_m=16, block_n=32,
                                    block_k=48, interpret=True)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.sum(want, axis=1)))

    # conv: cout split across blocks, check channel emitted once per image
    x_c, w_c, _, _ = _conv_case(rng, h=8, w=8, cin=4, cout=10)
    zp = jnp.int32(2)
    from repro.kernels.dispatch import _pad_zp, _resolve_pads
    pads = _resolve_pads(8, 8, 3, 3, (1, 1), "SAME")
    xp = _pad_zp(x_c, zp, pads)
    colsum = jnp.sum(w_c.astype(jnp.int32), axis=(0, 1, 2))
    wc = abft.conv_checksum_weight(w_c)
    acc, got = qconv2d_acc_checksum(xp, w_c, colsum, wc,
                                    zp.reshape(1), block_cout=4,
                                    interpret=True)
    ref = dispatch.conv_acc(x_c, zp, w_c, backend="jnp")
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.sum(ref, axis=3)))


# ---------------------------------------------------------------------------
# Fused checksum on the pallas path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_matmul_checksum_identity_clean(backend):
    rng = np.random.default_rng(5)
    x_q, w_q, _, _ = _mm_case(rng)
    w_check = abft.checksum_vector(w_q)
    acc, want = dispatch.matmul_acc_checksum(x_q, w_q, w_check,
                                             backend=backend)
    np.testing.assert_array_equal(np.asarray(jnp.sum(acc, axis=1)),
                                  np.asarray(want))


def test_pallas_fused_checksum_detects_every_bit():
    """ABFT on backend=pallas: the in-kernel check vector flags any single
    accumulator bit-flip and recovery restores the clean result exactly."""
    rng = np.random.default_rng(9)
    x_q, w_q, bias, scale = _mm_case(rng, m=8, k=40, n=12)
    clean, _ = dependable_qmatmul(Policy.ABFT, x_q, jnp.int32(3), w_q, bias,
                                  scale, jnp.int32(0), backend="pallas")
    for bit in (0, 7, 15, 23, 31):
        r, c = int(rng.integers(0, 8)), int(rng.integers(0, 12))

        def inject(acc, bit=bit, r=r, c=c):
            return acc.at[r, c].set(
                acc[r, c] ^ jnp.int32(np.int32(np.uint32(1) << np.uint32(bit))))

        y, st = dependable_qmatmul(Policy.ABFT, x_q, jnp.int32(3), w_q, bias,
                                   scale, jnp.int32(0), backend="pallas",
                                   inject=inject)
        assert int(st["faults_detected"]) >= 1, bit
        assert int(st["faults_corrected"]) >= 1, bit
        np.testing.assert_array_equal(np.asarray(y), np.asarray(clean))


def test_pallas_fused_conv_checksum_detects():
    rng = np.random.default_rng(13)
    x_q, w_q, bias, scale = _conv_case(rng)
    clean, _ = dependable_qconv2d(Policy.ABFT, x_q, jnp.int32(2), w_q, bias,
                                  scale, jnp.int32(0), backend="pallas")

    def inject(acc):
        return acc.at[1, 3, 2, 4].add(jnp.int32(1 << 19))

    y, st = dependable_qconv2d(Policy.ABFT, x_q, jnp.int32(2), w_q, bias,
                               scale, jnp.int32(0), backend="pallas",
                               inject=inject)
    assert int(st["faults_detected"]) >= 1
    np.testing.assert_array_equal(np.asarray(y), np.asarray(clean))


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------


def test_selection_precedence():
    assert backend_mod.default_backend() == "jnp"
    with backend_mod.use_backend("ref"):
        assert backend_mod.default_backend() == "ref"
        assert backend_mod.resolve(None).name == "ref"
        # per-call beats the scoped default
        assert backend_mod.resolve("pallas").name == "pallas"
        with backend_mod.use_backend("jnp"):
            assert backend_mod.resolve(None).name == "jnp"
        assert backend_mod.default_backend() == "ref"
    assert backend_mod.default_backend() == "jnp"


def test_unknown_backend_raises():
    with pytest.raises(KeyError, match="unknown backend"):
        backend_mod.get_backend("hpdp")
    with pytest.raises(KeyError):
        dependable_qmatmul(Policy.NONE, jnp.zeros((2, 2), jnp.int8),
                           jnp.int32(0), jnp.zeros((2, 2), jnp.int8),
                           jnp.zeros((2,), jnp.int32),
                           jnp.ones((2,), jnp.float32), jnp.int32(0),
                           backend="hpdp")


def test_backend_instances_resolve_directly():
    be = backend_mod.get_backend("ref")
    assert backend_mod.resolve(be) is be


def test_use_backend_routes_dependable_ops():
    """The scoped default reaches ops that never mention a backend."""
    rng = np.random.default_rng(21)
    x_q, w_q, bias, scale = _mm_case(rng, m=4, k=8, n=6)
    y_default, _ = dependable_qmatmul(Policy.NONE, x_q, jnp.int32(1), w_q,
                                      bias, scale, jnp.int32(0))
    with backend_mod.use_backend("pallas"):
        y_pallas, _ = dependable_qmatmul(Policy.NONE, x_q, jnp.int32(1), w_q,
                                         bias, scale, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(y_default), np.asarray(y_pallas))


# ---------------------------------------------------------------------------
# TMR correction counting (satellite: no more silent masking)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_tmr_counts_corrected_faults(backend):
    rng = np.random.default_rng(17)
    x_q, w_q, bias, scale = _mm_case(rng, m=8, k=16, n=12)

    def inject(acc):
        return acc.at[2, 5].add(jnp.int32(1 << 20))

    y_clean, st = dependable_qmatmul(Policy.TMR, x_q, jnp.int32(3), w_q, bias,
                                     scale, jnp.int32(0), backend=backend)
    assert int(st["faults_detected"]) == 0
    assert int(st["faults_corrected"]) == 0

    y, st = dependable_qmatmul(Policy.TMR, x_q, jnp.int32(3), w_q, bias,
                               scale, jnp.int32(0), inject=inject,
                               backend=backend)
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_corrected"]) == 1          # the vote masked it
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_clean))

    # DMR detects the same fault but corrects nothing — the gap is the
    # failover layer's workload
    _, st = dependable_qmatmul(Policy.DMR, x_q, jnp.int32(3), w_q, bias,
                               scale, jnp.int32(0), inject=inject,
                               backend=backend)
    assert int(st["faults_detected"]) == 1
    assert int(st["faults_corrected"]) == 0


def test_w8a8_transformer_backend_parity():
    """The per-layer rung end to end: a W8A8 transformer forward through
    models/api is bit-identical on cfg.backend = jnp vs pallas."""
    import dataclasses

    from repro.configs import registry
    from repro.models import api as model_api
    from repro.models import transformer
    from repro.models.config import reduced

    cfg = dataclasses.replace(reduced(registry.get("smollm-135m")),
                              quant="w8a8_ffn")
    params = model_api.init_params(cfg, jax.random.key(0))
    params = transformer.quantize_ffn_params(cfg, params)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    lo_jnp = model_api.forward(cfg, params, toks).logits
    lo_pal = model_api.forward(model_api.with_backend(cfg, "pallas"),
                               params, toks).logits
    np.testing.assert_array_equal(np.asarray(lo_jnp), np.asarray(lo_pal))


def test_stats_merge_tolerates_missing_keys():
    old = {"faults_detected": jnp.int32(2), "checks_run": jnp.int32(5)}
    merged = DependabilityStats.merge(DependabilityStats.zero(), old)
    assert int(merged["faults_detected"]) == 2
    assert int(merged["faults_corrected"]) == 0
    assert int(merged["checks_run"]) == 5


# ---------------------------------------------------------------------------
# Attention registry entries (the float hot kernel)
# ---------------------------------------------------------------------------


def _attn_case(seed=21, B=1, H=2, S=48, hd=16):
    kq, kk, kv = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(kq, (B, H, S, hd)),
            jax.random.normal(kk, (B, H, S, hd)),
            jax.random.normal(kv, (B, H, S, hd)))


@pytest.mark.parametrize("backend", BACKENDS)
def test_attn_registry_close_across_backends(backend):
    """Float attention is tolerance-parity across backends (unlike the
    exact integer ops); within one backend the checked entry must agree
    with the plain entry bit-for-bit."""
    q, k, v = _attn_case()
    out = dispatch.attn(q, k, v, backend=backend)
    out_jnp = dispatch.attn(q, k, v, backend="jnp")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_jnp),
                               rtol=2e-5, atol=2e-5)
    out2, check, csum = dispatch.attn_checksum(q, k, v, backend=backend)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    np.testing.assert_allclose(np.asarray(jnp.sum(out2, axis=-1)),
                               np.asarray(check), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(abft.output_row_checksums(out2)), np.asarray(csum))


def test_attn_entries_registered_on_all_builtins():
    for name in backend_mod.available_backends():
        be = backend_mod.get_backend(name)
        assert be.attn is not None and be.attn_checksum is not None, name
