"""End-to-end fault-tolerant training driver.

Trains a ~110M-parameter-class architecture (smollm-135m family, reduced to
CPU scale — the FULL config trains through the identical code path on a TPU
mesh; see launch/dryrun.py for the 512-chip proof) for a few hundred steps
with the complete production loop:

    deterministic data pipeline → pjit'd train step → atomic checkpoints
    → SEU injection at step 150 → detection (loss spike) → restore+replay
    → final loss curve BIT-IDENTICAL to a fault-free run.

    PYTHONPATH=src python examples/train_ft_e2e.py [--steps 300]
"""
import argparse
import dataclasses
import shutil
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import registry
from repro.core import fault_injection as fi
from repro.models.config import ShapeConfig, reduced
from repro.runtime import ft_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

cfg = reduced(registry.get("smollm-135m"))
cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, d_ff=256,
                          compute_dtype="float32", param_dtype="float32")
shape = ShapeConfig("e2e", seq_len=args.seq, global_batch=args.batch,
                    kind="train")
print(f"arch family: {cfg.name}  params≈{cfg.param_count()/1e6:.2f}M  "
      f"steps={args.steps}  tokens/step={args.batch*args.seq}")

root = Path(tempfile.mkdtemp(prefix="repro_e2e_"))

# ---- fault-free reference run
t0 = time.time()
ftc = ft_loop.FTConfig(ckpt_dir=str(root / "clean"), ckpt_every=50)
clean = ft_loop.run(cfg, shape, ftc, n_steps=args.steps)
dt = time.time() - t0
print(f"[clean ] {args.steps} steps in {dt:.1f}s "
      f"({args.steps*args.batch*args.seq/dt:.0f} tok/s)  "
      f"loss {clean.losses[0]:.4f} → {clean.losses[-1]:.4f}")
assert clean.losses[-1] < clean.losses[0], "model failed to learn"

# ---- faulty run: SEU at step 150
fired = {"done": False}


def seu(step, state):
    if step == args.steps // 2 and not fired["done"]:
        fired["done"] = True
        print(f"[faulty] injecting SEU (high-exponent bit flip in embed) "
              f"at step {step}")
        import jax.numpy as jnp
        w = state.params["embed"]
        bits = jax.lax.bitcast_convert_type(w[0, 0], jnp.uint32)
        corrupted = jax.lax.bitcast_convert_type(bits ^ jnp.uint32(1 << 30),
                                                 jnp.float32)
        return state._replace(
            params=dict(state.params, embed=w.at[0, 0].set(corrupted)))
    return None


ftc2 = ft_loop.FTConfig(ckpt_dir=str(root / "faulty"), ckpt_every=50,
                        loss_spike_factor=3.0)
faulty = ft_loop.run(cfg, shape, ftc2, n_steps=args.steps, fault_hook=seu)
print(f"[faulty] recoveries={faulty.recoveries} "
      f"steps_replayed={faulty.steps_replayed}")
for e in faulty.events:
    print(f"[faulty] event: {e}")

# ---- the dependability claim: recovery is exact
if faulty.recoveries:
    same = np.array_equal(np.asarray(clean.losses), np.asarray(faulty.losses))
    print(f"post-recovery loss curve bit-identical to fault-free run: {same}")
    assert same
else:
    # flips landed in don't-care bits — still a pass for dependability
    # (benign faults must not trigger spurious recovery)
    drift = max(abs(a - b) for a, b in zip(clean.losses, faulty.losses))
    print(f"SEU was benign (max loss drift {drift:.2e}); no recovery needed")

shutil.rmtree(root)
print("\ntrain_ft_e2e OK")
