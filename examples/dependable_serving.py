"""Dependable serving: the paper's execution flow, with live fault drills.

Payload computer → RTG4 → HPDP becomes: client → Engine → jitted decode
step.  Three drills prove the dependability story end to end:

  1. serve a batch of requests (continuous batching),
  2. SEU strikes the decode state mid-flight → snapshot rollback; final
     tokens are IDENTICAL to a fault-free run,
  3. SEU strikes the *weights* → TMR voting masks it (2-of-3 majority).

    PYTHONPATH=src python examples/dependable_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import fault_injection as fi
from repro.core import redundancy
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Engine, Request

cfg = reduced(registry.get("qwen3-0.6b"))
params = model_api.init_params(cfg, jax.random.key(0))
rng = np.random.default_rng(1)
prompts = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(3, 9))).tolist()
           for _ in range(6)]

print("=" * 70)
print(f"1. Continuous batching: 6 requests through capacity-3 engine "
      f"({cfg.name})")
print("=" * 70)


def serve(fault=False):
    eng = Engine(cfg, params, capacity=3, max_len=96, prefill_pad=8,
                 snapshot_every=2)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    if fault:
        for _ in range(3):
            eng.step()
        print("   [drill] SEU flips the sampled-token buffer …")
        eng.tokens = eng.tokens.at[0].set(int(eng.tokens[0]) ^ 0x40)
        lost = eng.restore_snapshot()
        print(f"   [drill] rolled back {lost} decode steps (bound = "
              f"snapshot_every = 2)")
    stats = eng.run()
    return reqs, stats


t0 = time.time()
clean_reqs, stats = serve(fault=False)
print(f"   {stats.tokens_out} tokens, {stats.steps} steps, "
      f"{stats.tokens_out/(time.time()-t0):.1f} tok/s")
for r in clean_reqs[:3]:
    print(f"   req{r.uid}: {r.output}")

print()
print("=" * 70)
print("2. SEU in decode state → snapshot rollback → identical output")
print("=" * 70)
faulty_reqs, stats = serve(fault=True)
same = all(a.output == b.output for a, b in zip(clean_reqs, faulty_reqs))
print(f"   replays={stats.replays}; outputs identical to fault-free run: {same}")
assert same

print()
print("=" * 70)
print("3. SEU in weights → TMR majority vote masks it")
print("=" * 70)
tok = jnp.asarray([1, 2, 3], jnp.int32)


def logits_fn(p):
    out = model_api.forward(cfg, p, tok[None, :])
    return out.logits


clean = logits_fn(params)
corrupt = fi.inject_into_pytree(params, jax.random.key(7), n_flips=1)
# three replicas, one with SEU-corrupted weights; majority vote masks it
r1 = logits_fn(params)
r2 = logits_fn(corrupt)
r3 = logits_fn(params)
masked = redundancy.vote([r1, r2, r3])
ok = bool(jnp.array_equal(masked, clean))
print(f"   single corrupted replica out-voted, output bit-exact: {ok}")
assert ok
print("\ndependable_serving OK")
