"""Quickstart: the paper's core op as a composable JAX module.

Runs the HPDP-style quantized conv+requant backend on one Ship-Detection
layer, verifies it against the float reference, then shows the same
parameter-driven design for a transformer qlinear — the "configure once,
stream parameters" idea that lets one compiled kernel serve every layer.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels
from repro.core import quant

print("=" * 70)
print("1. Paper's op: int8 conv + fused requantization (one compiled config,")
print("   weights/bias/requant params are runtime operands)")
print("=" * 70)

rng = np.random.default_rng(0)
# a reduced Table-1 layer: 24×3×3×24 on a 24×24×24 map
x = jnp.asarray(rng.standard_normal((1, 24, 24, 24)), jnp.float32) * 0.5
w = jnp.asarray(rng.standard_normal((3, 3, 24, 24)), jnp.float32) * 0.2
b = jnp.asarray(rng.standard_normal((24,)), jnp.float32) * 0.1

params = kernels.make_qconv_params(w, b)          # int8 weights + colsum
y_float = jax.lax.conv_general_dilated(
    x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")) + b

# calibrated activation qparams (min/max observer, as in core.quant)
x_scale, x_zp = quant.affine_qparams(float(x.min()), float(x.max()))
out_scale, out_zp = quant.affine_qparams(float(y_float.min()),
                                         float(y_float.max()))

y = kernels.qconv_act(x, params, x_scale, x_zp, out_scale, out_zp,
                        use_kernel=True, interpret=True)
err = float(jnp.abs(y - y_float).max())
print(f"conv out {y.shape}, max |int8 path − float path| = {err:.4f} "
      f"(≤ a few quantization steps of {float(out_scale):.4f})")
assert err < 6 * float(out_scale)

# same compiled configuration, NEW layer parameters — no recompilation
w2 = jnp.asarray(rng.standard_normal((3, 3, 24, 24)), jnp.float32) * 0.3
params2 = kernels.make_qconv_params(w2, b)
y2 = kernels.qconv_act(x, params2, x_scale, x_zp, out_scale, out_zp,
                         use_kernel=True, interpret=True)
print(f"second layer through the SAME kernel config: out {y2.shape} ✓")

print()
print("=" * 70)
print("2. Transformer-shaped rendition: int8 qlinear with fused requant")
print("=" * 70)
xt = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
wt = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32) * 0.1
lp = kernels.make_qlinear_params(wt)
xs, xzp = quant.affine_qparams(float(xt.min()), float(xt.max()))
os_, ozp = quant.affine_qparams(-8.0, 8.0)
yt = kernels.qlinear_act(xt, lp, xs, xzp, os_, ozp,
                             use_kernel=True, interpret=True)
yt_ref = xt @ wt
rel = float(jnp.linalg.norm(yt - yt_ref) / jnp.linalg.norm(yt_ref))
print(f"qlinear out {yt.shape}, relative error vs float = {rel:.4f}")
assert rel < 0.05

print()
print("=" * 70)
print("3. Dependability: exact integer ABFT catches an injected SEU")
print("=" * 70)
from repro.core import abft

x_q = jnp.asarray(rng.integers(-128, 128, (16, 64)), jnp.int8)
w_q = jnp.asarray(rng.integers(-127, 128, (64, 32)), jnp.int8)
acc = jnp.einsum("mk,kn->mn", x_q.astype(jnp.int32), w_q.astype(jnp.int32))
flipped = acc.at[3, 7].add(1 << 12)                  # single bit flip
wc = abft.checksum_vector(w_q)
clean_rows = abft.verify_rows(x_q, flipped, wc)      # True == clean
flagged = np.flatnonzero(~np.asarray(clean_rows))
print(f"ABFT flagged rows: {flagged} (expected [3])")
assert list(flagged) == [3]
res = abft.abft_qmatmul(x_q, jnp.int32(0), w_q, jnp.zeros((32,), jnp.int32),
                        inject=lambda a: a.at[3, 7].add(1 << 12))
np.testing.assert_array_equal(np.asarray(res.acc), np.asarray(acc))
print("recomputed flagged rows → output exact despite the fault ✓")

print("\nquickstart OK")
