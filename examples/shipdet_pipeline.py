"""The paper's full application: Ship-Detection CNN on the quantized backend.

Satellite frames stream through the quantized CNN (OBPMark-ML Ship
Detection topology, the paper's Table-1 trunk) exactly as the HPDP system
runs it: every conv layer executes as int8 conv + fused requantization with
layer parameters streamed in — and layer outputs chain directly into the
next layer (the HPDP→HPDP path).  Float reference runs side by side as the
validation (paper Fig. 4).

    PYTHONPATH=src python examples/shipdet_pipeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import shipdet

specs = shipdet.reduced_specs()      # same topology, CPU-sized maps
print(f"ship-detector: {len(specs)} conv layers "
      f"({sum(s.macs for s in specs)/1e6:.1f} M MACs reduced geometry)")

params = shipdet.init_params(specs, jax.random.key(0))

rng = np.random.default_rng(0)
frames = jnp.asarray(rng.standard_normal((2, specs[0].h, specs[0].w, 3)),
                     jnp.float32)

t0 = time.time()
q_out, _ = shipdet.forward(specs, params, frames, use_kernel=True,
                           interpret=True)
t_q = time.time() - t0
f_out = shipdet.float_forward(specs, params, frames)

err = float(jnp.abs(q_out - f_out).max())
step = float(params[-1]["out_scale"])
print(f"detection head out {q_out.shape}  (cls+box+obj per cell)")
print(f"quantized-vs-float: max abs {err:.4f} "
      f"({err/step:.1f} quantization steps of {step})")
assert err < 4 * step, "int8 pipeline diverged from float reference"

# per-layer agreement (the unit-test methodology of paper Fig. 4)
x = frames
print(f"\n{'layer':<12} {'out shape':<20} {'rel err':>8}")
for s, p in zip(specs, params):
    xq = shipdet.layer_forward(s, p, x, quantized=True)
    xf = shipdet.layer_forward(s, p, x, quantized=False)
    rel = float(jnp.linalg.norm(xq - xf) / (jnp.linalg.norm(xf) + 1e-9))
    print(f"{s.name:<12} {str(xq.shape):<20} {rel:8.4f}")
    x = jax.nn.relu(xq)          # chain the QUANTIZED stream (HPDP→HPDP)

print(f"\nforward wall time (quantized, CPU): {t_q*1e3:.1f} ms")
print("shipdet_pipeline OK")
