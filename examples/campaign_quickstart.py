"""Quickstart: run a small SEU fault-injection campaign programmatically.

    PYTHONPATH=src python examples/campaign_quickstart.py

Sweeps the paper's two hot-path primitives under all three dependability
policies, prints the coverage table, and shows how to drill one
configuration by hand (the API the CLI wraps).
"""
from __future__ import annotations

import jax

from repro.campaign import (
    CampaignSpec, build_case, expand_grid, resolve_fault_model, run_campaign,
    to_markdown, trial_keys, write_report)
from repro.campaign.runner import SUPPORTED
from repro.core.dependability import Policy


def main():
    # 1. A grid campaign: workloads × policies × sites × fault models.
    specs = expand_grid(
        workloads=["qmatmul", "qconv2d"],
        policies=[Policy.NONE, Policy.ABFT, Policy.TMR],
        sites=["accumulator", "weights"],
        fault_models=["single_bitflip", "stuck_at1"],
        trials=100, seed=0, supported=SUPPORTED)
    results = run_campaign(specs, log=print)
    print()
    print(to_markdown(results, {"example": "campaign_quickstart"}))
    write_report(results, "reports/quickstart", {"seed": 0})

    # 2. Drilling a single configuration by hand — the same pieces the
    #    runner composes: a case, a fault model, a deterministic key stream.
    spec = CampaignSpec("qmatmul", Policy.ABFT, "accumulator",
                        "single_bitflip", trials=500, seed=42)
    case = build_case(spec.workload, spec.seed)
    fault = resolve_fault_model(spec.fault_model)
    detected, mismatch = case.run_trials(spec.policy, spec.site, fault.apply,
                                         trial_keys(spec))
    print(f"hand-rolled drill: {detected.sum()}/{spec.trials} detected, "
          f"{mismatch.sum()} corrupted outputs "
          f"(ABFT zero-false-negative claim: detection == trials)")
    assert detected.all() and not mismatch.any()

    # 3. The same drill on the Pallas kernel path (docs/backends.md): the
    #    check vector is fused into the kernel as a second output, and the
    #    zero-false-negative claim must hold there too.
    pspec = CampaignSpec("qmatmul", Policy.ABFT, "accumulator",
                         "single_bitflip", trials=50, seed=42,
                         backend="pallas")
    pcase = build_case(pspec.workload, pspec.seed, pspec.backend)
    detected, mismatch = pcase.run_trials(pspec.policy, pspec.site,
                                          fault.apply, trial_keys(pspec))
    print(f"pallas-backend drill: {detected.sum()}/{pspec.trials} detected, "
          f"{mismatch.sum()} corrupted outputs")
    assert detected.all() and not mismatch.any()


if __name__ == "__main__":
    jax.config.update("jax_platform_name", "cpu")
    main()
