"""Fleet quickstart: dependable multi-replica serving, end to end.

Four acts, mirroring docs/fleet.md:

  1. serve a request stream through a 2-replica fleet (router + continuous
     batching) and check it against a single-engine reference,
  2. kill a replica mid-decode → deterministic failover, identical tokens,
  3. SEU strikes one replica's *weights* → ABFT scrub detects, checkpoint
     reload recovers, recalled requests replay — released stream identical,
  4. SEU strikes one replica's *decode state* → DMR pair-serving detects,
     replay restores the golden stream.

    PYTHONPATH=src python examples/fleet_quickstart.py
"""
import jax
import numpy as np

from repro.configs import registry
from repro.core import fault_injection as fi
from repro.core.dependability import Policy
from repro.fleet import Fleet
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Request

cfg = reduced(registry.get("smollm-135m"))
params = model_api.init_params(cfg, jax.random.key(0))
rng = np.random.default_rng(1)
prompts = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(3, 8))).tolist()
           for _ in range(6)]

fleet = Fleet(cfg, params, n_replicas=2, policy=Policy.NONE,
              capacity=3, max_len=96, prefill_pad=8, scrub_every=4)


def serve(policy, drill=None):
    fleet.reset(policy=policy)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        fleet.submit(r)
    if drill is not None:
        fleet.tick()
        fleet.tick()
        drill(fleet)
    fleet.run()
    return [list(fleet.released[r.uid].output) for r in reqs]


print("=" * 70)
print(f"1. 6 requests through a 2-replica fleet ({cfg.name})")
print("=" * 70)
golden = serve(Policy.NONE)
m = fleet.metrics
print(f"   released {m.released}/{m.submitted}, "
      f"{m.tokens_out} tokens in {m.ticks} ticks "
      f"(p50={m.p50_ticks:.0f} p99={m.p99_ticks:.0f} ticks)")
for uid, out in enumerate(golden[:3]):
    print(f"   req{uid}: {out}")

print()
print("=" * 70)
print("2. Kill replica 0 mid-decode → deterministic failover")
print("=" * 70)
outs = serve(Policy.NONE, drill=lambda f: f.kill_replica(0))
print(f"   failovers={fleet.metrics.failovers}, "
      f"lost_tokens={fleet.metrics.lost_tokens} "
      f"(bound {fleet.metrics.lost_work_bound_tokens}/replica-window)")
print(f"   outputs identical to fault-free run: {outs == golden}")
assert outs == golden

print()
print("=" * 70)
print("3. SEU in replica-0 weights → ABFT scrub + checkpoint-reload recovery")
print("=" * 70)


def strike_weights(f):
    v = f.replicas[0]
    print("   [drill] flipping one random bit of replica 0's parameters …")
    v.engine.params = fi.inject_pytree_with(
        v.engine.params, jax.random.key(7), fi.flip_one_bit)


outs = serve(Policy.ABFT, drill=strike_weights)
for e in fleet.supervisor.events:
    print(f"   {e}")
print(f"   detections={fleet.metrics.detections}, "
      f"recoveries={fleet.metrics.recoveries}, "
      f"replica 0 state={fleet.replicas[0].state.value}")
print(f"   released stream identical to fault-free run: {outs == golden}")
assert outs == golden
assert fleet.metrics.recoveries == 1

print()
print("=" * 70)
print("4. SEU in replica-0 decode state → DMR pair-serving detects + replays")
print("=" * 70)


def strike_state(f):
    v = f.replicas[0]
    print("   [drill] XOR-ing replica 0's sampled-token buffer …")
    v.engine.tokens = v.engine.tokens ^ 1


outs = serve(Policy.DMR, drill=strike_state)
print(f"   detections={fleet.metrics.detections}, "
      f"failovers={fleet.metrics.failovers}, "
      f"recoveries={fleet.metrics.recoveries} (transient ⇒ no reload)")
print(f"   released stream identical to fault-free run: {outs == golden}")
assert outs == golden

print("\nfleet_quickstart OK")
