"""Recovery quickstart: checkpoint/restart as a first-class policy.

Four acts, mirroring docs/recovery.md:

  1. op-level CKPT — a weight-memory SEU that ABFT can only *detect* is
     *healed* by rollback to the golden operand checkpoint,
  2. async incremental checkpointing — only dirty chunks hit disk, the
     chain restores bit-identically to a full checkpoint,
  3. decode-state scrubbing — a transient SEU in a live engine's KV cache
     is caught by checksum and rolled back to the verified snapshot,
  4. fleet CKPT policy — weight SEU → incremental restore of exactly the
     corrupted leaves, with the recovery wall-clock in the metrics.

    PYTHONPATH=src python examples/recovery_quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import abft, fault_injection as fi
from repro.core.dependability import Policy, dependable_qmatmul
from repro.fleet import Fleet
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Engine, Request
from repro.train import checkpoint as ckpt

rng = np.random.default_rng(0)

print("=" * 70)
print("1. Op-level CKPT: rollback heals the weight SEU ABFT only detects")
print("=" * 70)
x_q = jnp.asarray(rng.integers(-128, 128, (16, 64)), jnp.int8)
w_q = jnp.asarray(rng.integers(-127, 128, (64, 32)), jnp.int8)
bias = jnp.zeros((32,), jnp.int32)
scale = jnp.full((32,), 1e-3, jnp.float32)
w_check = abft.checksum_vector(w_q)          # deploy-time checksum
golden, _ = dependable_qmatmul(Policy.NONE, x_q, jnp.int32(0), w_q, bias,
                               scale, jnp.int32(0))

w_bad = fi.flip_one_bit(w_q, jax.random.key(1))      # SEU in weight memory
y_ab, st_ab = dependable_qmatmul(Policy.ABFT, x_q, jnp.int32(0), w_bad, bias,
                                 scale, jnp.int32(0), w_check=w_check)
y_ck, st_ck = dependable_qmatmul(Policy.CKPT, x_q, jnp.int32(0), w_bad, bias,
                                 scale, jnp.int32(0), w_check=w_check,
                                 ckpt=(x_q, w_q))    # golden checkpoint
print(f"ABFT: detected={int(st_ab['faults_detected'])}, output golden: "
      f"{bool(jnp.array_equal(y_ab, golden))}   (recompute re-reads bad storage)")
print(f"CKPT: detected={int(st_ck['faults_detected'])}, "
      f"recovered={int(st_ck['faults_recovered'])}, output golden: "
      f"{bool(jnp.array_equal(y_ck, golden))}")
assert jnp.array_equal(y_ck, golden)

print()
print("=" * 70)
print("2. Async incremental checkpointing: dirty chunks only, bit-exact")
print("=" * 70)
state = {"w": jnp.asarray(rng.standard_normal((256, 256)), jnp.float32),
         "step": jnp.asarray(0, jnp.int32)}
with tempfile.TemporaryDirectory() as d:
    with ckpt.IncrementalCheckpointer(d, chunk_bytes=16 * 1024) as c:
        c.save(1, state)
        state2 = {"w": state["w"].at[5, 5].set(9.0),
                  "step": jnp.asarray(2, jnp.int32)}   # tiny mutation
        c.save(2, state2)
        c.wait()
        print(f"saves={c.stats['saves']}  chunks written="
              f"{c.stats['chunks_written']}/{c.stats['chunks_total']} "
              f"(dirty fraction {c.dirty_fraction():.2f})")
    step, restored = ckpt.restore(d)                   # walks the chain
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state2["w"]))
    only_w = ckpt.restore_leaves(d, ["w"])             # partial restore
    print(f"restore(step {step}) bit-exact ✓   restore_leaves(['w']) → "
          f"{only_w['w'].shape} ✓")

print()
print("=" * 70)
print("3. Decode-state scrubbing: transient SEU → snapshot rollback")
print("=" * 70)
cfg = reduced(registry.get("smollm-135m"))
params = model_api.init_params(cfg, jax.random.key(0))
prompts = [[5, 9, 2], [3, 1, 4, 1]]


def serve(mode, strike=False):
    eng = Engine(cfg, params, capacity=2, max_len=64, prefill_pad=8,
                 snapshot_every=2, state_scrub=mode)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (eng.queue or eng.active) and steps < 100:
        eng.step()
        steps += 1
        if steps == 2 and strike:
            print("   [drill] SEU flips one bit of the live KV cache …")
            eng.cache = fi.inject_pytree_with(eng.cache, jax.random.key(7),
                                              fi.flip_one_bit)
    return [tuple(r.output) for r in reqs], eng


golden_stream, _ = serve("off")
stream, eng = serve("rollback", strike=True)
ev = eng.drain_state_events()
print(f"scrub events: {ev}")
print(f"streams identical to fault-free run: {stream == golden_stream} "
      f"(replayed ≤ snapshot_every steps)")
assert stream == golden_stream and ev and ev[0]["recovered"]

print()
print("=" * 70)
print("4. Fleet CKPT policy: weight SEU → incremental restore, measured")
print("=" * 70)
fleet = Fleet(cfg, params, n_replicas=2, policy=Policy.CKPT,
              capacity=2, max_len=64, prefill_pad=8, scrub_every=3,
              snapshot_every=2)


def fleet_serve(drill=False):
    fleet.reset(policy=Policy.CKPT)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        fleet.submit(r)
    if drill:
        fleet.tick()
        victim = fleet.replicas[0]
        victim.engine.params = fi.inject_pytree_with(
            victim.engine.params, jax.random.key(11), fi.flip_one_bit)
        print("   [drill] SEU flips one bit of replica 0's weights …")
    fleet.run()
    return [tuple(r.output) for r in reqs]


golden_fleet = fleet_serve()
stream = fleet_serve(drill=True)
m = fleet.metrics
print(f"detections={m.detections}  recoveries={m.recoveries}  "
      f"incremental_restores={m.incremental_restores}  "
      f"leaves_restored={m.leaves_restored}  "
      f"recovery={m.recovery_mean_seconds() * 1e3:.1f} ms")
for e in fleet.supervisor.events:
    print(f"   event: {e}")
assert stream == golden_fleet, "released stream must be golden"
assert m.incremental_restores == 1
fleet.close()

print("\nrecovery quickstart OK")
